"""Trace-driven serving benchmark: replay the pinned production-shape
trace (``repro.loadgen.pinned_spec``) through the real ``ServingLoop``
and emit the schema-versioned ``BENCH_serving.json`` scorecard.

The replay is fully deterministic on a CPU host: the trace is seeded,
and the clock is the roofline simulator's FULL-SIZE-config forward
latency (``repro.core.simulate.decode_forward_cost`` at ``TPU_V5E``)
injected as the loop's ``step_clock`` — the same pattern as
``benchmarks.calibration``.  Two same-seed runs must produce
byte-identical JSON (``--check`` asserts it; CI runs it per PR, so the
committed BENCH file tracks serving-latency drift across PRs).

The pinned serving config exercises every load-pressure policy at
once: a paged engine with a DELIBERATELY tight block pool (preemption
fires), ``AdmissionConfig`` backpressure + SLO-priority admission, and
a shared-prefix fleet tenant (prefix-cache hits).

Run:  PYTHONPATH=src python -m benchmarks.load_harness --requests 8 --out /tmp/BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.core import GranularitySpec, TPU_V5E
from repro.core.simulate import decode_forward_cost
from repro.loadgen import generate_trace, pinned_spec, replay_trace
from repro.loadgen.stats import itls, percentile, ttft
from repro.models import init_model
from repro.serving import (AdmissionConfig, DecodeEngine, PagedKVConfig,
                           ServingLoop)

from benchmarks.common import emit

SCHEMA_VERSION = 1
ARCH = "stablelm_3b"
MODE = "speculative"
SLOTS = 4
MAX_LEN = 256
KV_BLOCK = 16            # XLA reference path: block = paging granularity
KV_BLOCKS = 24           # tight pool: ~38% of dense parity -> preemption
MAX_WAITING = 6
EPS = 0.2

CSV_HEADER = ("rid,tenant,slo_class,arrival_s,ttft_s,itl_p95_s,"
              "n_tokens,preemptions,rejected")

SERVING_KEYS = ("requests", "tokens", "forwards", "tokens_per_forward",
                "preemptions", "resumes", "rejections",
                "prefill_forwards", "prefill_positions_computed",
                "prefill_positions_saved", "kv_preemptions",
                "kv_preempt_blocks_freed")


def _clock(cfg_full):
    """Roofline TPU-v5e latency of one (SLOTS, width) forward at
    context ell — the virtual clock every replay second comes from."""
    g = GranularitySpec.for_backend(
        cfg_full.ffn.n_experts,
        head_dim=(cfg_full.attention.head_dim if cfg_full.attention
                  else 128))

    def clock(width: int, ell: int) -> float:
        return decode_forward_cost(
            cfg_full, SLOTS, width, max(int(ell), 1), g).time(TPU_V5E)
    return clock


def build_loop(seed: int = 0) -> ServingLoop:
    """The pinned serving stack (reduced engine for CPU-runnable
    weights, full-size config for the clock)."""
    cfg = get_config(ARCH, reduced=True)
    params = init_model(jax.random.PRNGKey(seed), cfg)
    eng = DecodeEngine(cfg, params, batch=SLOTS, max_len=MAX_LEN,
                       paged=PagedKVConfig(block_size=KV_BLOCK,
                                           n_blocks=KV_BLOCKS))
    return ServingLoop(
        eng, mode=MODE, eps=EPS, step_clock=_clock(get_config(ARCH)),
        admission=AdmissionConfig(max_waiting=MAX_WAITING,
                                  preemption=True))


def run_harness(n_requests: int = 32, seed: int = 20260808) -> dict:
    """One replay -> the BENCH payload dict (sorted-key serializable)."""
    trace = generate_trace(pinned_spec(seed=seed, n_requests=n_requests))
    report = replay_trace(build_loop(), trace)
    serving = report["serving"]
    payload = {
        "schema_version": SCHEMA_VERSION,
        "bench": "serving_load_harness",
        "clock": report["clock"],
        "hardware": "tpu_v5e",
        "pinned": {
            "arch": ARCH, "mode": MODE, "slots": SLOTS,
            "max_len": MAX_LEN, "kv_block_size": KV_BLOCK,
            "kv_blocks": KV_BLOCKS, "max_waiting": MAX_WAITING,
            "preemption": True, "eps": EPS,
            "trace_seed": seed, "trace_requests": n_requests,
        },
        "trace_fingerprint": report["trace_fingerprint"],
        "makespan_s": report["makespan_s"],
        "metrics": report["metrics"],
        "serving": {k: serving[k] for k in SERVING_KEYS if k in serving},
    }
    payload["records"] = report["records"]       # stripped before dump
    return payload


def to_json(payload: dict) -> str:
    slim = {k: v for k, v in payload.items() if k != "records"}
    return json.dumps(slim, sort_keys=True, indent=1) + "\n"


def csv_rows(payload: dict) -> list:
    rows = [CSV_HEADER]
    for r in payload["records"]:
        gaps = itls(r)
        t = ttft(r)
        p95 = f"{percentile(gaps, 95):.9f}" if gaps else ""
        rows.append(f"{r.rid},{r.tenant},{r.slo_class},{r.arrival_s:.9f},"
                    f"{'' if t is None else f'{t:.9f}'},{p95},"
                    f"{r.n_tokens},{r.preemptions},{int(r.rejected)}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="scorecard path (repo root by convention)")
    ap.add_argument("--csv", default=None,
                    help="also write the per-request CSV here (nightly "
                         "artifact)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--seed", type=int, default=20260808)
    ap.add_argument("--check", action="store_true",
                    help="replay twice and assert byte-identical JSON "
                         "(the determinism gate CI runs)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    payload = run_harness(args.requests, args.seed)
    text = to_json(payload)
    if args.check:
        again = to_json(run_harness(args.requests, args.seed))
        if text != again:
            raise SystemExit("NON-DETERMINISTIC: same-seed replays "
                             "produced different BENCH JSON")
    m = payload["metrics"]
    emit("load_harness/ttft_p95", m.get("ttft_p95_s", 0.0) * 1e6,
         f"p50={m.get('ttft_p50_s', 0):.6f};p99={m.get('ttft_p99_s', 0):.6f};"
         f"completed={m['completed']};rejected={m['rejected']}")
    emit("load_harness/goodput", m["goodput_tok_s"],
         f"throughput={m['throughput_tok_s']:.2f};"
         f"attainment={m['slo_attainment']};"
         f"preemptions={m['preemptions']}")
    with open(args.out, "w") as f:
        f.write(text)
    print(f"wrote {args.out}")
    if args.csv:
        with open(args.csv, "w") as f:
            f.write("\n".join(csv_rows(payload)) + "\n")
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
