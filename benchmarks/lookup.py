"""Paper Table 24: deployment lookup — idle-compute baseline vs NFP
principle, with over-prediction factors.  Extended beyond the paper with
TPU v5e rows and all 10 assigned architectures (the survey the paper's
Sec. 6 proposes as 'a deployment lookup').
"""
from __future__ import annotations

from repro.configs import ARCH_IDS, get_config
from repro.core import (GranularitySpec, get_hardware, predict_dense,
                        predict_model, predict_moe_balanced,
                        predict_moe_skewed)


def _emit_row(name, pred):
    over = pred.overprediction
    over_s = f"{over:.1f}x" if over != float("inf") else "inf"
    print(f"{name},{pred.n_max:.0f},"
          f"idle={pred.n_idle if pred.n_idle != float('inf') else 'inf'};"
          f"limit={pred.limiting};over={over_s}")


def run(hw_names=("h20", "a800", "h800", "tpu_v5e")) -> None:
    g256 = GranularitySpec.for_backend(n_experts=256)
    # --- the paper's own Table 24 rows ------------------------------------
    for hw_name in ("h20", "a800", "h800"):
        hw = get_hardware(hw_name)
        for b in (1, 4, 8):
            _emit_row(f"lookup/paper/dense@{hw_name}/b{b}",
                      predict_dense(hw, g256, b))
        for k in (8, 32, 64):
            _emit_row(f"lookup/paper/moe_bal@{hw_name}/k{k}",
                      predict_moe_balanced(hw, g256, 256, k, 512))
        _emit_row(f"lookup/paper/moe_skew@{hw_name}/k8",
                  predict_moe_skewed(hw, g256, 8, 512))
    # --- beyond paper: the 10 assigned archs on TPU v5e -------------------
    hw = get_hardware("tpu_v5e")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        g = GranularitySpec.for_backend(cfg.ffn.n_experts)
        for b in (1, 8):
            for ell in (4096, 32768):
                pred = predict_model(cfg, hw, g, b, ell)
                _emit_row(f"lookup/tpu_v5e/{arch}/b{b}/L{ell}", pred)


if __name__ == "__main__":
    run()
