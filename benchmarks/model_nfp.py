"""Paper Fig. 4 / 26-37: full-model NFP principle validation.

Dense (WeDLM-8B analogue) across batch sizes and MoE (LLaDA-2.1-mini
analogue) across routing cases and sequence lengths: the NFP principle's
closed-form prediction vs the boundary extracted from the simulated
full-model T(N) (every module's physical work from the kernel padding
rules).  Also reports the limiting module — the paper's Sec. 5.2
"MoE-limited -> Attention-limited" shift with L.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import (GranularitySpec, extract_nmax, get_hardware,
                        latency_curve, predict_model)

from benchmarks.common import curve_from_pairs, emit, n_sweep


def run(hw_names=("tpu_v5e", "h20")) -> None:
    dense_cfg = get_config("wedlm8b_like")
    moe_cfg = get_config("llada_mini_like")
    g_dense = GranularitySpec.for_backend()
    g_moe = GranularitySpec.for_backend(n_experts=moe_cfg.ffn.n_experts)

    for hw_name in hw_names:
        hw = get_hardware(hw_name)
        # --- dense: batch sweep at L in {128..512} (paper G.2) -----------
        for ell in (128, 256, 512):
            for b in (1, 2, 4, 8):
                pairs = latency_curve(dense_cfg, hw, b, ell, n_sweep(512),
                                      g_dense)
                curve = curve_from_pairs(pairs)
                measured = extract_nmax(curve, 0.2)
                pred = predict_model(dense_cfg, hw, g_dense, b, ell)
                emit(f"model_nfp/dense@{hw_name}/L{ell}/b{b}",
                     curve.baseline_time * 1e6,
                     f"measured={measured};principle={pred.n_max:.0f};"
                     f"limit={pred.limiting};idle={pred.n_idle:.0f}")
        # --- MoE: routing x L sweep (paper G.3) ---------------------------
        from repro.core import balanced_moe_baseline_n
        for routing in ("balanced", "skewed"):
            base_n = (balanced_moe_baseline_n(moe_cfg.ffn.n_experts, 1,
                                              moe_cfg.ffn.top_k)
                      if routing == "balanced" else 1)
            for ell in (256, 4096, 16384, 32768):
                ns = sorted(set(n_sweep(512) + [base_n]))
                pairs = latency_curve(moe_cfg, hw, 1, ell, ns, g_moe,
                                      routing)
                curve = curve_from_pairs(pairs, baseline_n=base_n)
                measured = extract_nmax(curve, 0.2)
                pred = predict_model(moe_cfg, hw, g_moe, 1, ell, routing)
                emit(f"model_nfp/moe@{hw_name}/{routing}/L{ell}",
                     curve.baseline_time * 1e6,
                     f"measured={measured};principle={pred.n_max:.0f};"
                     f"limit={pred.limiting}")


if __name__ == "__main__":
    run()
