"""Paper Fig. 2 / 8-19 + Tables 20-23: MoE FFN module-level NFP.

Load-balanced (upper bound) and load-skewed (lower bound) controlled
routing, k swept 2..256, E=256, d_model=4096, expert d_ff=1024 (paper
App. C.3).  The physical padded-FLOPs staircase comes from the SAME
block-alignment math the Pallas kernel executes (core.granularity).

Balanced baseline is N_bal0 = ceil(E/(b*k)) (Eq. 26).
Predictions: balanced min(M_moe*E/k, tau) (module level: no attention
term), skewed M_moe.
"""
from __future__ import annotations

from repro.core import (GranularitySpec, balanced_moe_baseline_n,
                        extract_nmax, get_hardware, m_moe, moe_tau,
                        n_idle_moe)
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec
from repro.core.simulate import moe_ffn_cost

from benchmarks.common import curve_from_pairs, emit, n_sweep

E = 256
K_SWEEP = (2, 4, 8, 16, 32, 64, 128, 256)


def module_cfg(k: int) -> ArchConfig:
    return ArchConfig(
        name="moe-ffn-module", family="moe", n_layers=1, d_model=4096,
        vocab_size=1, attention=None,
        ffn=FFNSpec(kind="moe", d_ff=1024, activation="gelu",
                    n_experts=E, top_k=k))


def run(hw_names=("tpu_v5e", "h20")) -> None:
    gran = GranularitySpec.for_backend(n_experts=E)
    for hw_name in hw_names:
        hw = get_hardware(hw_name)
        for routing in ("balanced", "skewed"):
            for k in K_SWEEP:
                cfg = module_cfg(k)
                base_n = (balanced_moe_baseline_n(E, 1, k)
                          if routing == "balanced" else 1)
                pairs = []
                for n in sorted(set(n_sweep(1024) + [base_n])):
                    c = moe_ffn_cost(cfg, 1, n, gran, routing)
                    pairs.append((n, c.time(hw)))
                curve = curve_from_pairs(pairs, baseline_n=base_n)
                measured = extract_nmax(curve, 0.2)
                if routing == "balanced":
                    pred = min(gran.m_moe * E / k, moe_tau(E))
                    e_act = E
                else:
                    pred = gran.m_moe
                    e_act = k
                idle = n_idle_moe(hw.rho, 1, k, e_act, 1024)
                emit(f"moe_ffn/nmax@{hw_name}/{routing}/k{k}",
                     curve.baseline_time * 1e6,
                     f"measured={measured};principle={pred:.0f};"
                     f"idle={idle:.0f}")
                # staircase evidence (runtime padded FLOPs, Fig. 2d)
                f1 = moe_ffn_cost(cfg, 1, base_n, gran, routing)
                f2 = moe_ffn_cost(cfg, 1, base_n + 1, gran, routing)
                emit(f"moe_ffn/padded_flops@{hw_name}/{routing}/k{k}",
                     f1.flops / 1e6,
                     f"logical={f1.logical_flops/1e6:.1f};"
                     f"next_n_flops={f2.flops/1e6:.1f}")


if __name__ == "__main__":
    run()
