"""Serving throughput vs concurrency — the scheduler's NFP story.

Measures tokens/s through the budget-aware ServingLoop at 1/2/4/8
concurrent requests on the reduced CPU config, across all four
algorithm families (greedy / speculative / mtp / diffusion budget-split
modes).  The headline: positions per forward grow with concurrency but
stay inside N_max(eps), so batched serving rides the near-free region —
throughput scales with concurrency while per-forward latency stays near
the baseline.  Diffusion counts every refinement iteration as a forward
(plus the clean-KV commit forward), so its tok/fwd reflects the real
refine-forward budget spend.

With --kernel (serve through the Pallas ragged decode-attention path)
each row also carries that path's measured kernel-granularity slack
(mean query-row utilization inside the q_block tile, mean kv-tile
utilization, kv tiles skipped by the per-row ragged bounds) next to the
``core.nfp`` prediction (M_attn = the q_block): row_util ~= positions /
(slots * M_attn) is the paper's granularity-slack mechanism observed
per serving step.  Without --kernel the XLA reference path runs and no
slack columns are emitted (there is no tiling to measure).

The shared-prefix section (``--prefix-only`` to run alone,
``--no-prefix`` to skip) serves one common prompt head + unique tails
through dense vs paged(+prefix-cache) engines: the paged rows report
prefix hits and the prompt positions prefill never had to compute —
the serving-side win of the paged KV cache (docs/benchmarks.md).

Run:  PYTHONPATH=src python -m benchmarks.serving_throughput --kernel
      (interpret mode on CPU — slower, identical tokens)
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_model
from repro.serving import (DecodeEngine, PagedKVConfig, ServingLoop,
                           init_mtp_heads)

from benchmarks.common import emit

ARCH = "stablelm_3b"
PROMPT_LEN = 8
TOKENS = 24
MAX_LEN = 256
# shared-prefix workload: a system-prompt-like common prefix + short
# unique tails — the traffic shape prefix caching exists for
PREFIX_LEN = 48
TAIL_LEN = 6
KV_BLOCK = 16


def _mode_kwargs(cfg, mode: str):
    if mode == "mtp":
        return {"mtp_heads": init_mtp_heads(
            jax.random.PRNGKey(5), cfg.d_model, cfg.vocab_size, n_heads=4)}
    if mode == "diffusion":
        return {"refine_steps": 2}
    return {}


def _run_once(cfg, params, n_requests: int, mode: str, max_width: int,
              use_kernel: bool, paged=None, prompts=None, slots=None):
    slots = slots or min(n_requests, 8)
    eng = DecodeEngine(cfg, params, batch=slots, max_len=MAX_LEN,
                       use_kernel=use_kernel, paged=paged)
    loop = ServingLoop(eng, mode=mode, max_width=max_width,
                       **_mode_kwargs(cfg, mode))
    for i in range(n_requests):
        if prompts is not None:
            prompt = prompts[i]
        else:
            prompt = np.asarray(jax.random.randint(
                jax.random.PRNGKey(100 + i), (PROMPT_LEN,), 0,
                cfg.vocab_size))
        loop.submit(prompt, TOKENS)
    t0 = time.time()
    loop.run()
    return loop, loop.stats(), time.time() - t0


def _serve(cfg, params, n_requests: int, mode: str, max_width: int = 8,
           use_kernel: bool = False):
    # warmup pass: compiles every (batch, width) bucket this workload
    # hits (the module-level jit cache persists across engines), so the
    # timed pass below measures serving, not XLA compilation
    _run_once(cfg, params, n_requests, mode, max_width, use_kernel)
    return _run_once(cfg, params, n_requests, mode, max_width, use_kernel)


def run(modes=("greedy", "speculative", "mtp", "diffusion"),
        use_kernel: bool = False, prefix: bool = True) -> None:
    cfg = get_config(ARCH, reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    for mode in modes:
        for n_req in (1, 2, 4, 8):
            loop, stats, dt = _serve(cfg, params, n_req, mode,
                                     use_kernel=use_kernel)
            tput = stats["tokens"] / max(dt, 1e-9)
            us_fwd = dt / max(stats["forwards"], 1) * 1e6
            m_attn = loop.engine.gran.m_attn           # the NFP prediction
            slack = ""
            if "mean_kv_tile_util" in stats:
                slack = (f";m_attn={m_attn}"
                         f";row_util={stats['mean_attn_row_util']:.4f}"
                         f";tile_util={stats['mean_kv_tile_util']:.3f}"
                         f";tiles_skipped={stats['kv_tiles_skipped']}")
            emit(f"serving_throughput/{mode}/req{n_req}", us_fwd,
                 f"tok_s={tput:.1f};tok_fwd={stats['tokens_per_forward']:.2f};"
                 f"max_pos={stats['max_positions_per_forward']}" + slack)
    if prefix:
        run_shared_prefix(cfg, params, use_kernel=use_kernel)


def run_shared_prefix(cfg=None, params=None, n_requests: int = 8,
                      use_kernel: bool = False) -> None:
    """Shared-prefix workload: every request = one common PREFIX_LEN
    prompt head + a unique TAIL_LEN tail (multi-user traffic over one
    system prompt), streamed through 2 slots so admissions stagger and
    later requests find the head resident.  Dense serving prefills the
    shared head once per request; the paged cache's prefix hits skip it
    after the first admission — the ``derived`` column shows the prompt
    positions prefill actually computed (``prefill_pos``) vs the
    positions the cache absorbed (``prefill_saved``)."""
    if cfg is None:
        cfg = get_config(ARCH, reduced=True)
        params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    head = rng.integers(0, cfg.vocab_size, size=PREFIX_LEN)
    prompts = [np.concatenate([head,
                               rng.integers(0, cfg.vocab_size,
                                            size=TAIL_LEN)])
               for _ in range(n_requests)]
    variants = [
        ("dense", None),
        ("paged", PagedKVConfig(block_size=KV_BLOCK)),
        ("paged_nocache", PagedKVConfig(block_size=KV_BLOCK,
                                        prefix_cache=False)),
    ]
    for name, paged in variants:
        # warmup pass compiles this variant's buckets; timed pass below
        _run_once(cfg, params, n_requests, "greedy", 8, use_kernel,
                  paged=paged, prompts=prompts, slots=2)
        loop, stats, dt = _run_once(cfg, params, n_requests, "greedy", 8,
                                    use_kernel, paged=paged,
                                    prompts=prompts, slots=2)
        extra = ""
        if paged is not None:
            extra = (f";prefix_hits={stats['prefix_hits']}"
                     f"/{stats['prefix_lookups']}"
                     f";prefill_saved={stats['prefill_positions_saved']}"
                     f";blocks_peak={stats['kv_blocks_peak']}"
                     f";cow={stats['cow_copies']}")
        emit(f"serving_throughput/prefix/{name}/req{n_requests}",
             dt / max(stats["forwards"], 1) * 1e6,
             f"tok_s={stats['tokens'] / max(dt, 1e-9):.1f}"
             f";prefill_forwards={stats['prefill_forwards']}"
             f";prefill_pos={stats['prefill_positions_computed']}" + extra)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--modes", default="greedy,speculative,mtp,diffusion")
    ap.add_argument("--kernel", action="store_true",
                    help="serve through the Pallas ragged decode kernel "
                         "(interpret mode on CPU)")
    ap.add_argument("--prefix-only", action="store_true",
                    help="run only the shared-prefix paged-vs-dense "
                         "workload")
    ap.add_argument("--no-prefix", action="store_true",
                    help="skip the shared-prefix workload")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.prefix_only:
        run_shared_prefix(use_kernel=args.kernel)
    else:
        run(tuple(args.modes.split(",")), use_kernel=args.kernel,
            prefix=not args.no_prefix)


if __name__ == "__main__":
    main()
