"""Deliverable (g): roofline analysis per (arch x shape) on the
single-pod mesh (256 chips).

Three terms per cell (seconds, per chip):
  compute term    = FLOPs_per_chip / 197e12
  memory term     = HBM_bytes_per_chip / 819e9
  collective term = collective_bytes_per_chip / 50e9

Sources — two views, both reported:
  * analytic: the padded-work cost model (core.simulate) that reproduces
    the paper's module staircases; FLOPs/bytes are exact functions of the
    config + kernel block rules.  Per chip = global / 256 (the sharding
    distributes batch/experts/heads; imbalance shows up in the compiled
    view).  This is the PRIMARY source for the perf loop.
  * compiled: jax cost_analysis() + HLO collective parsing from the
    dry-run.  Collective bytes are while-loop trip-count aware (the
    dry-run parser walks the loop nesting), so they reflect the real
    per-step schedule.  CAVEAT: raw cost_analysis() FLOPs count each scan
    body once — reported for reference only; the analytic model is the
    FLOPs/bytes source.

MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference); the
useful-compute ratio MODEL_FLOPS / FLOPs flags remat/padding waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.core import GranularitySpec, TPU_V5E
from repro.core.simulate import (decode_forward_cost, full_forward_cost,
                                 train_step_cost)

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")
N_MICRO = {"mixtral_8x22b": 8, "phi3_medium_14b": 8}


def model_flops(rec: Dict) -> float:
    cfg = get_config(rec["arch"])
    n_active = cfg.param_count(active_only=True)
    tokens = rec["global_batch"] * (
        rec["seq_len"] if rec["mode"] != "decode"
        else rec.get("decode_positions", 1))
    mult = 6.0 if rec["mode"] == "train" else 2.0
    return mult * n_active * tokens


REMAT_FRACTION_OPT = {
    "phi3_medium_14b": 0.25, "stablelm_3b": 0.5, "starcoder2_3b": 0.5,
    "phi3_vision_4p2b": 0.5, "minicpm3_4b": 0.5,
}


def analytic_cost(rec: Dict):
    cfg = get_config(rec["arch"])
    gran = GranularitySpec.for_backend(
        cfg.ffn.n_experts,
        head_dim=cfg.attention.head_dim if cfg.attention else 128)
    b, s = rec["global_batch"], rec["seq_len"]
    variant = rec.get("variant", "baseline")
    if rec["mode"] == "train":
        n_micro = rec.get("n_micro", N_MICRO.get(rec["arch"], 4))
        remat_frac = (REMAT_FRACTION_OPT.get(rec["arch"], 1.0)
                      if variant == "opt" else 1.0)
        c = train_step_cost(cfg, b, s, gran, n_micro=n_micro)
        if remat_frac < 1.0:
            # fwd+bwd = 3x; remat recompute applies to the rematted frac
            scale = (3.0 + remat_frac) / 4.0
            for m in c.modules:
                if m.name != "adamw":
                    m.flops *= scale
                    m.logical_flops *= scale
        return c
    if rec["mode"] == "prefill":
        return full_forward_cost(cfg, b, s, gran)
    n_pos = rec.get("decode_positions", 1)
    return decode_forward_cost(cfg, b, n_pos, s, gran)


def scan_factor(rec: Dict) -> float:
    """Collectives are already loop-trip-corrected at dry-run time
    (dryrun.collective_bytes parses while-loop nesting); no further
    scaling here."""
    return 1.0


def analyze(rec: Dict, hw=TPU_V5E) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    cost = analytic_cost(rec)
    fl_chip = cost.flops / chips
    by_chip = cost.bytes / chips
    coll_raw = sum(rec["collective_bytes"].values())
    coll = coll_raw
    t_compute = fl_chip / hw.phi
    t_memory = by_chip / hw.beta
    t_coll = coll / hw.ici
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful = mf / cost.flops if cost.flops else 0.0
    t_bound = max(terms.values())
    t_model = mf / (hw.phi * chips)
    frac = t_model / t_bound if t_bound else 0.0
    return {
        "cell": (f'{rec["arch"]}/{rec["shape"]}'
                 + ("/OPT" if rec.get("variant") == "opt" else "")),
        "mode": rec["mode"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "analytic_flops": cost.flops,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "peak_bytes": rec["memory"]["peak_bytes"],
        "compiled_flops_raw": rec["cost"]["flops"],
        "collective_bytes_raw": coll_raw,
        "scan_factor": scan_factor(rec),
    }


def load(mesh: str = "singlepod", include_opt: bool = True) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              f"*__{mesh}*.json"))):
        if path.endswith("__opt.json") and not include_opt:
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(emit_markdown: bool = False) -> List[Dict]:
    rows = []
    for rec in load("singlepod"):
        a = analyze(rec)
        if a is None:
            continue
        rows.append(a)
        print(f'roofline/{a["cell"]},{a["t_compute_s"]*1e6:.1f},'
              f'mem_us={a["t_memory_s"]*1e6:.1f};'
              f'coll_us={a["t_collective_s"]*1e6:.1f};'
              f'dominant={a["dominant"]};'
              f'useful={a["useful_ratio"]:.3f};'
              f'roofline_frac={a["roofline_fraction"]:.3f}')
    if emit_markdown:
        print(markdown_table(rows))
    return rows


def markdown_table(rows: List[Dict]) -> str:
    out = ["| cell | compute (ms) | memory (ms) | collective (ms) | "
           "bottleneck | useful | roofline frac | peak GiB/chip |",
           "|---|---|---|---|---|---|---|---|"]
    for a in rows:
        out.append(
            f'| {a["cell"]} | {a["t_compute_s"]*1e3:.3f} '
            f'| {a["t_memory_s"]*1e3:.3f} | {a["t_collective_s"]*1e3:.3f} '
            f'| {a["dominant"]} | {a["useful_ratio"]:.3f} '
            f'| {a["roofline_fraction"]:.3f} '
            f'| {a["peak_bytes"]/2**30:.2f} |')
    return "\n".join(out)


if __name__ == "__main__":
    run(emit_markdown=True)
