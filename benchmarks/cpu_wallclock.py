"""CPU wall-clock sanity layer (DESIGN.md §5 evidence level 3).

Real silicon T(N) sweeps at small module shapes: demonstrates the
flat-then-rise latency shape and the paper's measurement protocol
(warmup, rounds, median-of-medians) on actual hardware.  Absolute values
are CPU-specific — the TPU-target numbers come from the simulator and
the dry-run roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import extract_nmax, sweep_callable

from benchmarks.common import emit

D_MODEL, D_FF = 512, 1408
L_CACHE = 2048
HEADS, HEAD_DIM = 8, 64


def dense_ffn_sweep():
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (D_MODEL, D_FF), jnp.float32)
    w2 = jax.random.normal(key, (D_FF, D_MODEL), jnp.float32)

    def make(n):
        x = jax.random.normal(key, (n, D_MODEL), jnp.float32)

        @jax.jit
        def f(x):
            return (x @ w1) @ w2
        f(x).block_until_ready()
        return lambda: f(x)

    ns = [1, 2, 4, 8, 16, 32, 64, 128]
    curve = sweep_callable(make, ns, warmup=2, rounds=3, iters=5)
    nmax = extract_nmax(curve, 0.2)
    for n, t in zip(curve.ns, curve.times):
        emit(f"cpu_wallclock/dense_ffn/N{n}", t * 1e6)
    emit("cpu_wallclock/dense_ffn/nmax", curve.baseline_time * 1e6,
         f"measured={nmax}")


def attention_sweep():
    key = jax.random.PRNGKey(1)
    kc = jax.random.normal(key, (1, L_CACHE, HEADS, HEAD_DIM), jnp.float32)
    vc = jax.random.normal(key, (1, L_CACHE, HEADS, HEAD_DIM), jnp.float32)

    from repro.kernels.decode_attention.ref import decode_attention_ref

    def make(n):
        q = jax.random.normal(key, (1, n, HEADS, HEAD_DIM), jnp.float32)

        @jax.jit
        def f(q):
            return decode_attention_ref(q, kc, vc, L_CACHE - n)
        f(q).block_until_ready()
        return lambda: f(q)

    ns = [1, 2, 4, 8, 16, 32, 64]
    curve = sweep_callable(make, ns, warmup=2, rounds=3, iters=5)
    nmax = extract_nmax(curve, 0.2)
    for n, t in zip(curve.ns, curve.times):
        emit(f"cpu_wallclock/attention/N{n}", t * 1e6)
    emit("cpu_wallclock/attention/nmax", curve.baseline_time * 1e6,
         f"measured={nmax}")


def run() -> None:
    dense_ffn_sweep()
    attention_sweep()


if __name__ == "__main__":
    run()
