"""Empirical NFP calibration — the paper's over-prediction table, closed
into the serving loop.

Two halves:

1. **Over-prediction table** (paper Table 24, system edition): for a
   Dense / MoE / SSM config per serve mode and context bucket,
   calibrate the empirical knee (``repro.autotune.calibrate``, roofline
   simulator as the latency source — the CPU host cannot time the
   TPU-target forward) and report analytic vs calibrated budgets.  The
   ``over`` column is analytic/calibrated (>= 1 by the downward-only
   clamp; > 1 where the analytic budget over-spends), ``idle_over`` is
   the paper's idle-compute ratio (up to ~23x at paper scale).

2. **Serving comparison**: the REAL ``ServingLoop`` (reduced engine —
   it supplies genuine serving dynamics: admission, acceptance, width
   splitting) serves the same workload twice in speculative mode, with
   the full-size config's simulated forward latency injected as the
   loop's ``step_clock``.  The STATIC loop spends the raw analytic
   budget; the CALIBRATED loop runs the ``BudgetController`` seeded
   from the full-size table.  Emitted per arch: the max per-forward
   latency ratio vs the width-1 baseline.  The headline: the
   controlled loop never exceeds (1+eps), while the static analytic
   budget demonstrably does on the MoE config (its tau-branch budget
   ignores that every extra width activates more experts the width-1
   baseline never paid for).

``--out-dir`` additionally writes the calibration-table JSON artifacts
and an ``overprediction.csv`` (the nightly CI job uploads both).

Run:  PYTHONPATH=src python -m benchmarks.calibration --requests 6 --tokens 12
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.autotune import (BudgetController, calibrate_specs, save_table)
from repro.configs import get_config
from repro.core import GranularitySpec, TPU_V5E
from repro.core.simulate import decode_forward_cost
from repro.models import init_model
from repro.serving import DecodeEngine, ServingLoop, init_mtp_heads

from benchmarks.common import emit

ARCHS = ("stablelm_3b", "granite_moe_3b_a800m", "falcon_mamba_7b")
MODES = ("greedy", "speculative", "mtp", "diffusion")
SLOTS = 4
MAX_LEN = 256
PROMPT_LEN = 8
EPS = 0.2
BUCKETS = (256, 1024, 4096)

CSV_HEADER = ("arch,mode,ell,use_kernel,analytic,measured,calibrated,"
              "n_idle,overprediction,idle_overprediction,limiting,"
              "baseline_us")


def _gran(cfg) -> GranularitySpec:
    return GranularitySpec.for_backend(
        cfg.ffn.n_experts,
        head_dim=(cfg.attention.head_dim if cfg.attention else 128))


def _table(cfg, modes, eps: float = EPS):
    """Full-size-config calibration table (simulator latency source)."""
    return calibrate_specs(cfg, TPU_V5E, _gran(cfg), batch=SLOTS,
                           modes=modes, eps=eps, buckets=BUCKETS)


def _clock(cfg, table):
    """step_clock: TPU-target latency of one (SLOTS, width) forward at
    the entry bucket covering ell — the same simulator, granularity,
    and bucket-lookup rule (``CalibrationTable.lookup``) the
    calibration sweep and controller use, so the controller's seeded
    baseline matches the observations exactly."""
    g = _gran(cfg)

    def clock(width: int, ell: int) -> float:
        bucket = table.lookup(None, ell).ell
        return decode_forward_cost(cfg, SLOTS, width, bucket, g).time(TPU_V5E)
    return clock


def overprediction_rows(arch: str, table) -> list:
    rows = []
    for e in sorted(table.entries, key=lambda e: (e.mode, e.ell)):
        rows.append(
            f"{arch},{e.mode},{e.ell},{int(e.use_kernel)},"
            f"{e.analytic_nmax},{e.measured_nmax},{e.calibrated_budget},"
            f"{e.n_idle:.1f},{e.overprediction:.3f},"
            f"{e.idle_overprediction:.3f},{e.limiting},"
            f"{e.baseline_time * 1e6:.3f}")
    return rows


def serve_once(arch: str, mode: str, n_requests: int, tokens: int,
               controller, clock, max_width: int = 16):
    """One ServingLoop run on the reduced engine with the injected
    clock; returns (loop, stats)."""
    cfg = get_config(arch, reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, batch=SLOTS, max_len=MAX_LEN)
    kwargs = {}
    if mode == "mtp":
        kwargs["mtp_heads"] = init_mtp_heads(
            jax.random.PRNGKey(5), cfg.d_model, cfg.vocab_size, n_heads=4)
    loop = ServingLoop(eng, mode=mode, eps=EPS, max_width=max_width,
                       controller=controller, step_clock=clock, **kwargs)
    for i in range(n_requests):
        prompt = np.asarray(jax.random.randint(
            jax.random.PRNGKey(100 + i), (PROMPT_LEN,), 0, cfg.vocab_size))
        loop.submit(prompt, tokens)
    loop.run()
    return loop, loop.stats()


def max_clock_ratio(loop, clock) -> float:
    """Max per-forward latency vs the width-1 baseline at the same
    context — the Eq. 4 quantity, computed from the loop's actual
    forwards under the same clock both loops observed."""
    return max((clock(e["width"], e["ell"]) / clock(1, e["ell"])
                for e in loop.step_log), default=1.0)


def run_serving_comparison(arch: str, n_requests: int, tokens: int,
                           mode: str = "speculative") -> dict:
    cfg_full = get_config(arch)
    table = _table(cfg_full, modes=(mode,))
    clock = _clock(cfg_full, table)
    static, s_stats = serve_once(arch, mode, n_requests, tokens,
                                 controller=None, clock=clock)
    ctrl = BudgetController(table=table)
    controlled, c_stats = serve_once(arch, mode, n_requests, tokens,
                                     controller=ctrl, clock=clock)
    res = {
        "static_max_ratio": max_clock_ratio(static, clock),
        "controlled_max_ratio": max_clock_ratio(controlled, clock),
        "controlled_observed_max": c_stats.get("max_latency_ratio", 1.0),
        "static_tokens_per_forward": s_stats["tokens_per_forward"],
        "controlled_tokens_per_forward": c_stats["tokens_per_forward"],
        "controller": c_stats.get("controller", {}),
    }
    for name in ("static", "controlled"):
        r = res[f"{name}_max_ratio"]
        emit(f"calibration/serving/{arch}/{mode}/{name}", r,
             f"max_latency_ratio={r:.3f};"
             f"within_tolerance={'yes' if r <= 1 + EPS + 1e-9 else 'NO'};"
             f"tok_fwd={res[f'{name}_tokens_per_forward']:.2f}")
    return res


def run(archs=ARCHS, modes=MODES, n_requests: int = 6, tokens: int = 12,
        out_dir=None, serve: bool = True) -> dict:
    csv_rows = [CSV_HEADER]
    results = {}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    for arch in archs:
        cfg = get_config(arch)
        table = _table(cfg, modes=modes)
        for e in sorted(table.entries, key=lambda e: (e.mode, e.ell)):
            emit(f"calibration/{arch}/{e.mode}/L{e.ell}",
                 e.baseline_time * 1e6,
                 f"analytic={e.analytic_nmax};measured={e.measured_nmax};"
                 f"calibrated={e.calibrated_budget};"
                 f"over={e.overprediction:.2f};"
                 f"idle_over={e.idle_overprediction:.2f};lim={e.limiting}")
        csv_rows.extend(overprediction_rows(arch, table))
        if out_dir:
            save_table(table, os.path.join(out_dir,
                                           f"calibration_{arch}.json"))
        results[arch] = {"table": table}
        if serve:
            results[arch].update(
                run_serving_comparison(arch, n_requests, tokens))
    if out_dir:
        with open(os.path.join(out_dir, "overprediction.csv"), "w") as f:
            f.write("\n".join(csv_rows) + "\n")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--modes", default=",".join(MODES))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--tokens", type=int, default=12)
    ap.add_argument("--out-dir", default=None,
                    help="write calibration-table JSON + overprediction "
                         "CSV artifacts here")
    ap.add_argument("--no-serve", action="store_true",
                    help="tables only; skip the ServingLoop comparison")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(tuple(args.archs.split(",")), tuple(args.modes.split(",")),
        n_requests=args.requests, tokens=args.tokens,
        out_dir=args.out_dir, serve=not args.no_serve)


if __name__ == "__main__":
    main()
