"""Paper App. I (Tables 17-23): tolerance-threshold sensitivity.

N_max extracted at eps in {0.05, 0.10, 0.15, 0.20, 0.30} for the Dense
FFN (batch sweep), Attention (L sweep) and MoE (k sweep, both routings)
modules.  Granularity-governed modules must be ~eps-invariant; Dense FFN
may shift by one sampled step.
"""
from __future__ import annotations

from repro.core import (GranularitySpec, balanced_moe_baseline_n,
                        get_hardware, sensitivity_sweep)
from repro.core.simulate import (attention_core_cost, dense_ffn_cost,
                                 moe_ffn_cost)

from benchmarks.attention import MODULE_CFG as ATTN_CFG
from benchmarks.common import curve_from_pairs, emit, n_sweep
from benchmarks.dense_ffn import MODULE_CFG as DENSE_CFG
from benchmarks.moe_ffn import E, module_cfg

EPS = (0.05, 0.10, 0.15, 0.20, 0.30)


def _fmt(sweep):
    return ";".join(f"eps{e}={v}" for e, v in sorted(sweep.items()))


def run(hw_names=("tpu_v5e",)) -> None:
    gran = GranularitySpec.for_backend(n_experts=E)
    for hw_name in hw_names:
        hw = get_hardware(hw_name)
        for b in (1, 4, 16):
            pairs = [(n, dense_ffn_cost(DENSE_CFG, b, n).time(hw))
                     for n in n_sweep(1024)]
            c = curve_from_pairs(pairs)
            emit(f"sensitivity/dense@{hw_name}/b{b}",
                 c.baseline_time * 1e6, _fmt(sensitivity_sweep(c, EPS)))
        for ell in (256, 4096, 32768):
            pairs = [(n, attention_core_cost(ATTN_CFG, 1, n, ell, gran)
                      .time(hw)) for n in n_sweep(512)]
            c = curve_from_pairs(pairs)
            emit(f"sensitivity/attn@{hw_name}/L{ell}",
                 c.baseline_time * 1e6, _fmt(sensitivity_sweep(c, EPS)))
        for routing in ("balanced", "skewed"):
            for k in (8, 64, 256):
                cfg = module_cfg(k)
                base_n = (balanced_moe_baseline_n(E, 1, k)
                          if routing == "balanced" else 1)
                pairs = [(n, moe_ffn_cost(cfg, 1, n, gran, routing).time(hw))
                         for n in sorted(set(n_sweep(1024) + [base_n]))]
                c = curve_from_pairs(pairs, baseline_n=base_n)
                emit(f"sensitivity/moe@{hw_name}/{routing}/k{k}",
                     c.baseline_time * 1e6, _fmt(sensitivity_sweep(c, EPS)))


if __name__ == "__main__":
    run()
