"""Paper Fig. 3 / 20-25 + Tables 18-19: Attention module-level NFP.

Decode MHA over a KV cache (n_heads=32, head_dim=128, d_kv=4096, paper
App. C.4), L swept 256..32k.  The measured boundary comes from the
simulated T(N) whose physical FLOPs use OUR Pallas kernel's q-tile
padding; the idle-compute prediction is Eq. 11.  The headline result is
L-independence of N_max (= q_block) vs the L-dependent idle prediction.
"""
from __future__ import annotations

from repro.core import (GranularitySpec, extract_nmax, get_hardware,
                        m_attn, n_idle_attn)
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec
from repro.core.simulate import attention_core_cost

from benchmarks.common import curve_from_pairs, emit, n_sweep

MODULE_CFG = ArchConfig(
    name="attn-module", family="dense", n_layers=1, d_model=4096,
    vocab_size=1,
    attention=AttentionSpec(kind="gqa", n_heads=32, n_kv_heads=32,
                            head_dim=128),
    ffn=FFNSpec(kind="none"))

L_SWEEP = (256, 512, 1024, 2048, 4096, 8192, 16384, 32768)


def run(hw_names=("tpu_v5e", "h20")) -> None:
    gran = GranularitySpec.for_backend()
    for hw_name in hw_names:
        hw = get_hardware(hw_name)
        for ell in L_SWEEP:
            pairs = []
            for n in n_sweep(512):
                c = attention_core_cost(MODULE_CFG, 1, n, ell, gran)
                pairs.append((n, c.time(hw)))
            curve = curve_from_pairs(pairs)
            measured = extract_nmax(curve, 0.2)
            idle = n_idle_attn(hw.rho, ell)
            emit(f"attention/nmax@{hw_name}/L{ell}",
                 curve.baseline_time * 1e6,
                 f"measured={measured};tile_pred={m_attn()};"
                 f"idle={idle if idle != float('inf') else 'inf'}")
        # staircase evidence: padded FLOPs jump exactly at q_block
        qb = m_attn()
        c_at = attention_core_cost(MODULE_CFG, 1, qb, 8192, gran)
        c_over = attention_core_cost(MODULE_CFG, 1, qb + 1, 8192, gran)
        emit(f"attention/tile_staircase@{hw_name}", c_at.flops / 1e6,
             f"flops_at_tile={c_at.flops/1e6:.1f};"
             f"flops_over={c_over.flops/1e6:.1f}")


if __name__ == "__main__":
    run()
