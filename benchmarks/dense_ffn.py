"""Paper Fig. 1 / 5-7: Dense FFN module-level NFP.

Sweeps T(N) for the isolated two-GEMM FFN across batch sizes, extracts
N_max(0.2), and compares with the idle-compute prediction rho*s/(2b).
Paper module shape: d_model=4096, d_ff=9216 (LLaDA-2.1-Flash dims).

Rows:
  dense_ffn/T@{hw}/b{b}/N{n}          — modeled module latency (us)
  dense_ffn/nmax@{hw}/b{b}            — derived: measured;predicted
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import (GranularitySpec, TPU_V5E, extract_nmax, get_hardware,
                        n_idle_dense)
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec
from repro.core.simulate import dense_ffn_cost

from benchmarks.common import curve_from_pairs, emit, n_sweep

MODULE_CFG = ArchConfig(
    name="dense-ffn-module", family="dense", n_layers=1, d_model=4096,
    vocab_size=1,
    attention=AttentionSpec(n_heads=32, n_kv_heads=32, head_dim=128),
    ffn=FFNSpec(kind="dense", d_ff=9216, activation="gelu"))

BATCHES = (1, 2, 4, 8, 16, 32)


def run(hw_names=("tpu_v5e", "h20")) -> None:
    for hw_name in hw_names:
        hw = get_hardware(hw_name)
        for b in BATCHES:
            pairs = []
            for n in n_sweep(2048):
                c = dense_ffn_cost(MODULE_CFG, b, n)
                t = c.time(hw)
                pairs.append((n, t))
                if n in (1, 16, 64, 256):
                    emit(f"dense_ffn/T@{hw_name}/b{b}/N{n}", t * 1e6,
                         c.bound(hw))
            curve = curve_from_pairs(pairs)
            measured = extract_nmax(curve, 0.2)
            predicted = n_idle_dense(hw.rho, b)
            emit(f"dense_ffn/nmax@{hw_name}/b{b}", curve.baseline_time * 1e6,
                 f"measured={measured};idle_pred={predicted:.1f}")


if __name__ == "__main__":
    run()
