"""Ragged per-slot decode attention: granularity slack vs slot mix.

Sweeps the ragged Pallas decode-attention kernel over mixed-length slot
distributions at verification widths N = 1..16 (the scheduler's
per-request positions) and reports the kernel's physical work next to
the logical work:

  - uniform:   every slot at the same mid length (the aligned baseline —
               zero ragged win, pure q_block padding slack),
  - bimodal:   half the slots short, half long (continuous batching after
               a wave of admissions),
  - one_long:  one long slot, the rest short (the straggler pattern that
               scalar-length kernels pay worst-case kv work for).

For each point: wall time of one kernel call (interpret mode on CPU —
relative, not absolute), executed vs grid kv tiles (the per-row skip
win), and query-row utilization inside the q_block tile (the M_attn
slack the NFP principle prices; rows = slots * q_block physically).

Run:  PYTHONPATH=src python -m benchmarks.ragged_decode [--widths 1,2,4,8,16]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.ops import (decode_attention_ragged,
                                                slack_report)

from benchmarks.common import emit

B = 8            # slots
S_MAX = 512      # allocated cache length
H, KV, DH = 8, 2, 64


def slot_mixes(s_max: int, b: int):
    short, long_ = 32, s_max - 32
    mid = s_max // 2
    return {
        "uniform": np.full(b, mid, np.int64),
        "bimodal": np.asarray([short, long_] * (b // 2), np.int64),
        "one_long": np.asarray([long_] + [short] * (b - 1), np.int64),
    }


def _time_call(q, kc, vc, lens, iters: int = 3) -> float:
    out = decode_attention_ragged(q, kc, vc, lens, interpret=True)
    out.block_until_ready()                       # compile + warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        decode_attention_ragged(q, kc, vc, lens,
                                interpret=True).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def run(widths) -> None:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    kc = jax.random.normal(ks[1], (B, S_MAX, KV, DH), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S_MAX, KV, DH), jnp.float32)
    for dist, lens_np in slot_mixes(S_MAX, B).items():
        lens = jnp.asarray(lens_np, jnp.int32)
        for n in widths:
            q = jax.random.normal(ks[0], (B, n, H, DH), jnp.float32)
            us = _time_call(q, kc, vc, lens)
            rep = slack_report(n, lens_np, S_MAX, head_dim=DH)
            emit(f"ragged_decode/{dist}/n{n}", us,
                 f"q_block={rep['q_block']};row_util={rep['row_utilization']:.4f};"
                 f"tiles_exec={rep['kv_tiles_executed']};"
                 f"tiles_grid={rep['kv_tiles_grid']};"
                 f"tiles_skipped={rep['kv_tiles_skipped']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--widths", default=",".join(str(i) for i in range(1, 17)))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run([int(w) for w in args.widths.split(",")])


if __name__ == "__main__":
    main()
