"""Benchmark harness entry point — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``
Prints ``name,us_per_call,derived`` CSV.  Sections:
  dense_ffn     — paper Fig. 1 / 5-7
  moe_ffn       — paper Fig. 2 / 8-19, Tables 20-23
  attention     — paper Fig. 3 / 20-25, Tables 18-19
  model_nfp     — paper Fig. 4 / 26-37
  sensitivity   — paper App. I Tables 17-23
  lookup        — paper Table 24 (+ TPU v5e / 10-arch extension)
  roofline      — brief deliverable (g), from dry-run artifacts
  cpu_wallclock — real-silicon sanity sweeps
  serving_throughput — scheduler tokens/s vs concurrency (NFP budget)
  calibration   — empirical NFP calibration + budget-controlled serving
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (attention, calibration, cpu_wallclock,
                            dense_ffn, lookup, model_nfp, moe_ffn,
                            roofline, sensitivity, serving_throughput)
    print("name,us_per_call,derived")
    sections = [
        ("dense_ffn", dense_ffn.run),
        ("moe_ffn", moe_ffn.run),
        ("attention", attention.run),
        ("model_nfp", model_nfp.run),
        ("sensitivity", sensitivity.run),
        ("lookup", lookup.run),
        ("roofline", roofline.run),
        ("cpu_wallclock", cpu_wallclock.run),
        ("serving_throughput", serving_throughput.run),
        ("calibration", calibration.run),
    ]
    failed = []
    for name, fn in sections:
        try:
            fn()
        except Exception as e:                                # noqa: BLE001
            failed.append(name)
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark sections failed: {failed}")


if __name__ == "__main__":
    main()
