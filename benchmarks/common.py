"""Shared helpers for the benchmark suite.

Output convention: ``name,us_per_call,derived`` CSV rows (one per
measurement), where us_per_call is the modeled/measured latency of one
decode forward and derived carries the benchmark-specific headline
(N_max, over-prediction factor, ...).
"""
from __future__ import annotations

import sys
from typing import Iterable, List, Optional, Sequence

from repro.core import LatencyCurve


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def curve_from_pairs(pairs, baseline_n: int = 1) -> LatencyCurve:
    ns = [int(n) for n, _ in pairs]
    ts = [float(t) for _, t in pairs]
    return LatencyCurve(ns, ts, baseline_n)


def n_sweep(limit: int = 1024) -> List[int]:
    """Dense sweep at small N (where granularity boundaries live), then
    16-aligned steps including every power of two — the paper's sampled
    decode-position sets land on tile/padding boundaries."""
    ns = list(range(1, 33))
    step = [40, 48, 56, 64, 80, 96, 112, 128, 160, 192, 224, 256, 320,
            384, 448, 512, 640, 768, 896, 1024, 1280, 1536, 1792, 2048]
    ns += [v for v in step if v <= limit]
    # one-past-boundary probes expose the staircase edges
    ns += [v + 1 for v in (64, 128, 256, 512) if v + 1 <= limit]
    return sorted(set(ns))
