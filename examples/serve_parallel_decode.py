"""Parallel-decoding serving demo: AR baseline vs NFP-budgeted
speculative decoding vs diffusion-style block decoding on one model.

Demonstrates the paper's capacity-normalized evaluation (Sec. J.2.3):
the same system-side budget, different algorithm-side utilization.

Run: PYTHONPATH=src python examples/serve_parallel_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_model
from repro.serving import (DecodeEngine, DiffusionBlockDecoder,
                           SpeculativeDecoder)

TOKENS = 48


def main():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                                cfg.vocab_size)

    def fresh():
        return DecodeEngine(cfg, params, batch=1, max_len=512)

    # --- AR baseline (N=1 per forward) ------------------------------------
    eng = fresh()
    t0 = time.perf_counter()
    ar = np.asarray(eng.greedy_generate(prompt, TOKENS)[0])
    t_ar = time.perf_counter() - t0
    print(f"AR greedy:       {TOKENS} tokens, {TOKENS} forwards, "
          f"{t_ar:.2f}s")

    # --- speculative, verification length from the NFP budget -------------
    eng = fresh()
    budget = eng.nfp_budget()
    spec = SpeculativeDecoder(eng, gamma=min(budget - 1, 8))
    t0 = time.perf_counter()
    toks, stats = spec.generate(prompt, TOKENS)
    t_spec = time.perf_counter() - t0
    print(f"speculative:     {stats['tokens']} tokens, "
          f"{stats['forwards']} forwards "
          f"({stats['tokens_per_forward']:.2f} tok/fwd, "
          f"utilization {stats['position_utilization']:.2f}), {t_spec:.2f}s")
    print(f"  lossless vs AR: {bool(np.array_equal(ar, toks[:TOKENS]))}  "
          f"(NFP budget={budget})")

    # --- diffusion-style block decode --------------------------------------
    eng = fresh()
    diff = DiffusionBlockDecoder(eng, block_size=min(budget - 1, 12),
                                 refine_steps=3)
    t0 = time.perf_counter()
    dtoks, dstats = diff.generate(prompt, TOKENS)
    t_diff = time.perf_counter() - t0
    print(f"diffusion-block: {dstats['tokens']} tokens, "
          f"{dstats['forwards']} forwards "
          f"({dstats['tokens_per_forward']:.2f} tok/fwd, "
          f"utilization {dstats['position_utilization']:.2f}), {t_diff:.2f}s")
    print("\ncapacity-normalized view: all methods spend positions from the"
          "\nsame near-free budget; tokens/forward is the algorithm-side"
          "\nutilization the paper separates from system capacity.")


if __name__ == "__main__":
    main()
