"""End-to-end training driver: ~100M-parameter dense LM for a few hundred
steps on the synthetic pipeline, with checkpoint/restart fault tolerance.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

The ~100M config is a starcoder2-family model (same code path as the
full 3B); --tiny switches to the smoke config for CI-speed runs.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec
from repro.data import DataConfig, make_pipeline
from repro.dist.elastic import StepWatchdog
from repro.models import init_model
from repro.training import AdamWConfig, init_opt_state, make_train_step


def model_100m() -> ArchConfig:
    return ArchConfig(
        name="dense-100m", family="dense", n_layers=8, d_model=768,
        vocab_size=32768,
        attention=AttentionSpec(kind="gqa", n_heads=12, n_kv_heads=4,
                                head_dim=64),
        ffn=FFNSpec(kind="dense", d_ff=2048, activation="swiglu"),
        tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    from repro.configs import get_config
    cfg = get_config("starcoder2_3b", reduced=True) if args.tiny \
        else model_100m()
    params = init_model(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M")

    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, n_micro=2))
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch))
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=2)
    start = 0
    if latest_step(args.ckpt_dir) is not None:    # restart-after-failure
        (restored, meta) = restore(args.ckpt_dir,
                                   {"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        start = int(meta.get("step", 0))
        print(f"resumed from checkpoint at step {start}")

    watchdog = StepWatchdog(deadline_s=120.0)
    t_last = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        dt = time.time() - t_last
        t_last = time.time()
        watchdog.observe(dt)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss={float(metrics['loss']):.4f}  "
                  f"ce={float(metrics['ce']):.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}  "
                  f"lr={float(metrics['lr']):.2e}  {dt:.2f}s/step")
        if step and step % args.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt}, {"step": step})
    ckpt.save(args.steps, {"params": params, "opt": opt},
              {"step": args.steps})
    ckpt.wait()
    print("done; final checkpoint committed")


if __name__ == "__main__":
    main()
