"""Quickstart: the NFP principle in five minutes.

1. Pick an architecture config and hardware.
2. Ask the NFP predictor how many decode positions are near-free.
3. Build a tiny model, run a multi-position decode forward, and check
   the simulated latency curve against the closed-form prediction.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (GranularitySpec, TPU_V5E, H20, LatencyCurve,
                        extract_nmax, latency_curve, predict_model)
from repro.models import init_model
from repro.serving import DecodeEngine


def main():
    # ---- 1. the paper's headline: idle-compute over-predicts -------------
    cfg = get_config("llada_mini_like")          # MoE: E=256, k=8
    gran = GranularitySpec.for_backend(n_experts=cfg.ffn.n_experts)
    pred = predict_model(cfg, H20, gran, b=1, ell=4096)
    print(f"[{cfg.name} @ H20]  NFP principle: N_max ~= {pred.n_max:.0f} "
          f"(limited by {pred.limiting})")
    from repro.core import predict_moe_balanced
    mod = predict_moe_balanced(H20, gran, cfg.ffn.n_experts, cfg.ffn.top_k,
                               cfg.ffn.d_ff)
    print(f"  module-level idle-compute intuition says {mod.n_idle:.0f} -> "
          f"over-predicts {mod.overprediction:.0f}x (paper Table 24)")

    # ---- 2. on the deployment target (TPU v5e) ---------------------------
    pred_tpu = predict_model(cfg, TPU_V5E, gran, b=1, ell=4096)
    print(f"[{cfg.name} @ TPU v5e]  N_max ~= {pred_tpu.n_max:.0f} "
          f"(limited by {pred_tpu.limiting}, rho={TPU_V5E.rho:.0f})")

    # ---- 3. simulated T(N) curve agrees with the closed form -------------
    from repro.core import balanced_moe_baseline_n
    base_n = balanced_moe_baseline_n(cfg.ffn.n_experts, 1, cfg.ffn.top_k)
    ns = sorted(set(range(1, 129)) | {base_n})
    pts = latency_curve(cfg, TPU_V5E, 1, 4096, ns, gran)
    curve = LatencyCurve([n for n, _ in pts], [t for _, t in pts],
                         baseline_n=base_n)   # Eq. 26 balanced baseline
    print(f"  simulated N_max(0.2) = {extract_nmax(curve, 0.2)} "
          f"(baseline N_bal0={base_n}); T(N_bal0) = "
          f"{curve.baseline_time*1e6:.0f}us")

    # ---- 4. run an ACTUAL multi-position decode forward (tiny model) -----
    small = get_config("llada_mini_like", reduced=True)
    params = init_model(jax.random.PRNGKey(0), small)
    eng = DecodeEngine(small, params, batch=1, max_len=128)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                small.vocab_size)
    eng.prefill(prompt)
    budget = eng.nfp_budget()
    n = min(budget, 16)
    logits = eng.decode_step(jax.random.randint(jax.random.PRNGKey(2),
                                                (1, n), 0, small.vocab_size))
    print(f"  tiny-model engine: budget={budget}, ran one decode forward "
          f"with N={n}, logits {logits.shape}")


if __name__ == "__main__":
    main()
