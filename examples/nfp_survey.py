"""NFP deployment survey: the paper's Table 24 as a living lookup over
all 10 assigned architectures x hardware targets x batch x context.

Run: PYTHONPATH=src python examples/nfp_survey.py
"""
from repro.configs import ARCH_IDS, get_config
from repro.core import (GranularitySpec, get_hardware, predict_model)


def main():
    print(f"{'arch':26s} {'hw':8s} {'b':>3s} {'L':>6s} "
          f"{'N_max':>6s} {'idle':>8s} {'over':>6s}  limiting")
    for hw_name in ("tpu_v5e", "h20", "h800"):
        hw = get_hardware(hw_name)
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            g = GranularitySpec.for_backend(cfg.ffn.n_experts)
            for b in (1, 8):
                for ell in (4096, 32768):
                    p = predict_model(cfg, hw, g, b, ell)
                    idle = (f"{p.n_idle:.0f}" if p.n_idle != float("inf")
                            else "inf")
                    over = (f"{p.overprediction:.1f}x"
                            if p.overprediction != float("inf") else "-")
                    print(f"{arch:26s} {hw_name:8s} {b:3d} {ell:6d} "
                          f"{p.n_max:6.0f} {idle:>8s} {over:>6s}  "
                          f"{p.limiting}")


if __name__ == "__main__":
    main()
