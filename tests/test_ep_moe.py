"""Expert parallelism (shard_map + all_to_all) vs the single-device MoE.

Runs on 8 placeholder host devices — must execute before any other test
initializes jax with 1 device, hence the subprocess isolation.
"""
import json
import subprocess
import sys

import pytest

# 8-device shard_map subprocess — by far the suite's longest setup
# (minutes of XLA host-platform compilation); nightly lane
pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.arch import FFNSpec
from repro.models.moe import init_moe, moe_ffn
from repro.dist.ep_moe import ep_moe_ffn

mesh = jax.make_mesh((2, 4), ('data', 'model'))
key = jax.random.PRNGKey(0)
x = jax.random.normal(jax.random.PRNGKey(1), (32, 64), jnp.float32)
xs = jax.device_put(x, NamedSharding(mesh, P('model', None)))
res = {}
for e, k in [(8, 2), (6, 2), (16, 4)]:
    f = FFNSpec(kind='moe', d_ff=32, activation='swiglu', n_experts=e,
                top_k=k)
    params = init_moe(key, 64, f, dtype=jnp.float32)
    ref, _ = moe_ffn(params, f, x)
    out = ep_moe_ffn(params, f, xs, mesh, capacity_factor=8.0)
    res[f'e{e}_k{k}'] = float(jnp.max(jnp.abs(np.asarray(out)
                                              - np.asarray(ref))))
# capacity drops: tiny capacity must still run and produce finite output
f = FFNSpec(kind='moe', d_ff=32, activation='swiglu', n_experts=8, top_k=2)
params = init_moe(key, 64, f, dtype=jnp.float32)
out = ep_moe_ffn(params, f, xs, mesh, capacity_factor=0.25)
res['drops_finite'] = bool(jnp.all(jnp.isfinite(out)))
print('RESULT::' + json.dumps(res))
"""


@pytest.fixture(scope="module")
def ep_results():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=480,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT::")][0]
    return json.loads(line[len("RESULT::"):])


def test_ep_matches_reference_divisible(ep_results):
    assert ep_results["e8_k2"] < 1e-4
    assert ep_results["e16_k4"] < 1e-4


def test_ep_matches_reference_padded_experts(ep_results):
    assert ep_results["e6_k2"] < 1e-4


def test_ep_capacity_drops_are_safe(ep_results):
    assert ep_results["drops_finite"]
