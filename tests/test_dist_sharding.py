"""Sharding rules: pspec assignment, divisibility fallbacks, policies.

Pure pspec logic — runs against a duck-typed mesh (axis sizes only), so
no placeholder-device process isolation is needed."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import (batch_pspec, cache_pspecs, mesh_axes,
                                 opt_pspecs, param_pspecs)
from repro.models.transformer import init_cache, init_model
from repro.training import init_opt_state


class FakeMesh:
    def __init__(self, shape_map):
        self.axis_names = tuple(shape_map)
        self.shape = dict(shape_map)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.fixture(scope="module")
def params_sds():
    cfg = get_config("stablelm_3b")            # full size: divisible dims
    key = jax.random.PRNGKey(0)
    return cfg, jax.eval_shape(lambda k: init_model(k, cfg), key)


def test_mesh_axes():
    assert mesh_axes(SINGLE) == ("data", "model")
    assert mesh_axes(MULTI) == (("pod", "data"), "model")


def test_batch_pspec_divisibility():
    assert batch_pspec(SINGLE, 64) == P("data", None)
    assert batch_pspec(SINGLE, 8) == P(None, None)       # 8 % 16 != 0
    assert batch_pspec(MULTI, 64) == P(("pod", "data"), None)
    assert batch_pspec(MULTI, 2) == P("pod", None)       # partial use
    assert batch_pspec(SINGLE, 256, include_model=True) == \
        P(("data", "model"), None)


def test_param_pspecs_roles(params_sds):
    cfg, params = params_sds
    ps = param_pspecs(params, SINGLE, policy="tp_only")
    seg0 = ps["segments"][0]
    # column-parallel: output dim; row-parallel: input dim; norms replicated
    assert seg0["attn"]["wq"][-1] == "model"
    assert seg0["attn"]["wo"][-2] == "model"
    assert seg0["ffn"]["up"][-1] == "model"
    assert seg0["ffn"]["down"][-2] == "model"
    assert all(d is None for d in seg0["ln1"]["scale"])


def test_param_pspecs_policies(params_sds):
    cfg, params = params_sds
    dp = param_pspecs(params, SINGLE, policy="dp_only")
    assert all(all(d is None for d in p)
               for p in jax.tree.leaves(dp, is_leaf=lambda x: isinstance(x, P)))
    fsdp = param_pspecs(params, SINGLE, policy="fsdp")
    wq = fsdp["segments"][0]["attn"]["wq"]
    assert "model" in wq and any(d == "data" for d in wq)
    with pytest.raises(ValueError):
        param_pspecs(params, SINGLE, policy="zigzag")


def test_param_pspecs_respect_divisibility():
    # a dim not divisible by the axis size must stay unsharded
    params = {"wq": jax.ShapeDtypeStruct((100, 30), jnp.float32)}
    ps = param_pspecs(params, SINGLE, policy="tp_only")
    assert ps["wq"] == P(None, None)


def test_opt_pspecs_mirror_and_step(params_sds):
    cfg, params = params_sds
    p_ps = param_pspecs(params, SINGLE, policy="fsdp")
    opt = jax.eval_shape(init_opt_state, params)
    o_ps = opt_pspecs(opt, p_ps)
    assert o_ps["step"] == P()
    assert (o_ps["m"]["segments"][0]["attn"]["wq"]
            == p_ps["segments"][0]["attn"]["wq"])


def test_cache_pspecs_modes(params_sds):
    cfg, _ = params_sds
    cache = jax.eval_shape(lambda: init_cache(cfg, 64, 4096))
    head = cache_pspecs(cache, SINGLE, 64, mode="head")
    seq = cache_pspecs(cache, SINGLE, 64, mode="seq")
    k_head = head["segments"][0]["k"]          # (L, b, s, kv_heads, dh)
    k_seq = seq["segments"][0]["k"]
    assert k_head[1] == "data"
    assert k_seq[2] == "model" and ("model" not in tuple(k_head)[2:3])
    with pytest.raises(ValueError):
        cache_pspecs(cache, SINGLE, 64, mode="paged")
