"""Hypothesis property tests for ``core/nfp.py`` (Eqs. 5-14).

Three families the paper's algebra promises for ALL inputs, not just the
Table-24 points that ``test_nfp_core`` pins:
  - AI curves are monotone non-decreasing in N (more positions per
    forward never lowers arithmetic intensity),
  - idle boundaries scale with rho (a roofline with more FLOPs per byte
    tolerates more positions; dense is exactly linear in rho),
  - each principle (Eq. 12 dense, Eq. 13 MoE balanced, Eq. 14 MoE
    skewed) equals the min of its terms and is attained by the
    first-exiting module (``limiting`` names the argmin).

Runs under real hypothesis when installed, or the deterministic
``tests/conftest.py`` fallback sweep otherwise.
"""
import math

from hypothesis import given, settings, strategies as st

from repro.core import (GranularitySpec, H20, TPU_V5E, ai_attn, ai_dense,
                        ai_moe, n_idle_attn, n_idle_dense, n_idle_moe,
                        predict_dense, predict_model, predict_moe_balanced,
                        predict_moe_skewed)
from repro.core.hardware import HardwareSpec

G256 = GranularitySpec.for_backend(n_experts=256)


def _hw(rho: float) -> HardwareSpec:
    """A synthetic roofline at the given FLOPs/byte balance point."""
    return HardwareSpec(name=f"synth{rho:g}", phi=rho * 1e12, beta=1e12)


# ===========================================================================
# AI curves monotone in N
# ===========================================================================

class TestAIMonotoneInN:
    @given(n=st.integers(1, 4096), b=st.integers(1, 64))
    @settings(max_examples=100, deadline=None)
    def test_dense(self, n, b):
        assert ai_dense(n + 1, b) >= ai_dense(n, b)

    @given(n=st.integers(1, 4096), ell=st.integers(1, 65536))
    @settings(max_examples=100, deadline=None)
    def test_attn(self, n, ell):
        assert ai_attn(n + 1, ell) >= ai_attn(n, ell)

    @given(n=st.integers(1, 4096), b=st.integers(1, 16),
           k=st.sampled_from([1, 2, 8, 32]),
           d_ff=st.sampled_from([128, 512, 2048]))
    @settings(max_examples=100, deadline=None)
    def test_moe(self, n, b, k, d_ff):
        assert ai_moe(n + 1, b, k, 256, d_ff) >= ai_moe(n, b, k, 256, d_ff)

    @given(n=st.integers(1, 4096), ell=st.integers(1, 65536))
    @settings(max_examples=50, deadline=None)
    def test_attn_ai_saturates_at_2l_over_s(self, n, ell):
        # Eq. 21: AI(N) = 2NL/((L+N)s) < 2L/s for every N — the context
        # length caps attention intensity no matter the parallelism (the
        # paper's memory-bound slack source)
        assert ai_attn(n, ell) < 2.0 * ell / 2.0      # s = 2 bytes (bf16)


# ===========================================================================
# Idle boundaries scale with rho
# ===========================================================================

class TestIdleScalesWithRho:
    @given(rho=st.floats(10.0, 1000.0), c=st.floats(1.1, 8.0),
           b=st.integers(1, 64))
    @settings(max_examples=100, deadline=None)
    def test_dense_linear_in_rho(self, rho, c, b):
        # Eq. 9 is exactly linear: N_idle(c*rho) = c * N_idle(rho)
        assert math.isclose(n_idle_dense(c * rho, b),
                            c * n_idle_dense(rho, b), rel_tol=1e-9)

    @given(rho=st.floats(10.0, 500.0), c=st.floats(1.1, 4.0),
           ell=st.integers(64, 65536))
    @settings(max_examples=100, deadline=None)
    def test_attn_monotone_in_rho(self, rho, c, ell):
        # more FLOPs per byte -> later balance point (inf once memory-bound
        # for all N: 2L <= rho*s)
        assert n_idle_attn(c * rho, ell) >= n_idle_attn(rho, ell)

    @given(rho=st.floats(10.0, 500.0), c=st.floats(1.1, 4.0),
           k=st.sampled_from([2, 8, 32]))
    @settings(max_examples=100, deadline=None)
    def test_moe_monotone_in_rho(self, rho, c, k):
        a = n_idle_moe(rho, 1, k, e_act=256, d_ff=512)
        b = n_idle_moe(c * rho, 1, k, e_act=256, d_ff=512)
        assert b >= a

    @given(b=st.integers(1, 64))
    @settings(max_examples=20, deadline=None)
    def test_dense_boundary_via_synthetic_hardware(self, b):
        # the same scaling observed through a HardwareSpec roofline
        lo, hi = _hw(100.0), _hw(400.0)
        assert n_idle_dense(hi.rho, b) > n_idle_dense(lo.rho, b)
        assert math.isclose(hi.rho / lo.rho, 4.0, rel_tol=1e-6)


# ===========================================================================
# The principles: min of terms, attained by the first-exiting module
# ===========================================================================

def _assert_min_attained(p):
    assert p.n_max == min(p.terms.values())
    assert p.terms[p.limiting] == p.n_max
    assert p.limiting in p.terms


class TestPrinciplesAreMins:
    @given(b=st.integers(1, 128))
    @settings(max_examples=100, deadline=None)
    def test_dense_eq12(self, b):
        p = predict_dense(H20, G256, b=b)
        _assert_min_attained(p)
        # Eq. 12 terms literally: min(rho*s/2b, M_attn)
        assert p.n_max == min(n_idle_dense(H20.rho, b), float(G256.m_attn))

    @given(e=st.sampled_from([8, 64, 256]), k=st.sampled_from([1, 2, 8, 32]),
           d_ff=st.sampled_from([128, 512, 2048]))
    @settings(max_examples=100, deadline=None)
    def test_moe_balanced_eq13(self, e, k, d_ff):
        if k > e:
            return
        g = GranularitySpec.for_backend(n_experts=e)
        p = predict_moe_balanced(H20, g, n_experts=e, k=k, d_ff=d_ff)
        _assert_min_attained(p)
        assert p.n_max == min(g.m_moe * e / k, float(g.tau), float(g.m_attn))

    @given(k=st.sampled_from([1, 2, 8, 32]),
           d_ff=st.sampled_from([128, 512, 2048]))
    @settings(max_examples=50, deadline=None)
    def test_moe_skewed_eq14(self, k, d_ff):
        p = predict_moe_skewed(H20, G256, k=k, d_ff=d_ff)
        _assert_min_attained(p)
        assert p.n_max == min(float(G256.m_moe), float(G256.m_attn))
        # skew never exceeds balanced (paper: skew is the lower bound)
        bal = predict_moe_balanced(H20, G256, n_experts=256, k=k, d_ff=d_ff)
        assert p.n_max <= bal.n_max

    @given(b=st.integers(1, 32), ell=st.integers(64, 65536),
           arch=st.sampled_from(["stablelm_3b", "mixtral_8x22b",
                                 "falcon_mamba_7b", "zamba2_1p2b"]),
           routing=st.sampled_from(["balanced", "skewed"]))
    @settings(max_examples=60, deadline=None)
    def test_model_composition_min(self, b, ell, arch, routing):
        from repro.configs import get_config
        cfg = get_config(arch)
        g = GranularitySpec.for_backend(cfg.ffn.n_experts or 0)
        p = predict_model(cfg, TPU_V5E, g, b, ell, routing=routing)
        _assert_min_attained(p)
