"""The repro.analysis static analyzer: fixture-driven checker behaviour
(bad patterns flagged, clean idioms silent), the real tree vs. its
committed baseline, and the compile-discipline regression the analyzer
exists to protect (steady-state serving must never recompile).

Fast lane: fixture projects are tiny tmp_path packages parsed by the
AST index directly; the Pallas capture harness runs the real kernels'
ops entries eagerly on CPU in ~2s (module-scoped)."""
import json
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.analysis import (Project, diff_against_baseline, load_baseline,
                            run_checkers, write_baseline)
from repro.analysis import baseline as baseline_mod
from repro.analysis import host_sync, pallas_contracts, recompile
from repro.analysis.cli import find_repo_root, main
from repro.analysis.findings import Finding
from repro.analysis.granularity_drift import (check_drift, declared_tiles,
                                              launched_tiles)
from repro.analysis.pallas_contracts import (CapturedLaunch,
                                             capture_launches, check_launch)
from repro.configs import get_config
from repro.models import init_model
from repro.serving import DecodeEngine, ServingLoop
from repro.serving.engine import _decode_fn

ROOT = find_repo_root(Path(__file__).resolve().parent)


# ===========================================================================
# fixture projects
# ===========================================================================

def make_project(tmp_path, **modules) -> Project:
    src = tmp_path / "src"
    pkg = src / "pkg"
    pkg.mkdir(parents=True, exist_ok=True)
    (pkg / "__init__.py").write_text("")
    for name, code in modules.items():
        (pkg / f"{name}.py").write_text(textwrap.dedent(code))
    return Project(src, rel_to=tmp_path)


BAD_LOOP = '''
    import jax
    import jax.numpy as jnp
    import numpy as np

    @jax.jit
    def model_step(x):
        return x + 1

    class Loop:
        def __init__(self):
            self.state = jnp.zeros((4,))

        def step(self):
            y = model_step(self.state)
            n = int(y[0])
            host = np.asarray(y)
            vals = y.tolist()
            acc = 0.0
            for v in y:
                acc += 1.0
            jax.block_until_ready(y)
            return n, host, vals, acc
'''

CLEAN_LOOP = '''
    import jax
    import jax.numpy as jnp
    import numpy as np

    @jax.jit
    def model_step(x):
        return x + 1

    class Loop:
        def __init__(self):
            self.state = jnp.zeros((4,))
            self.count = 0

        def step(self):
            y = model_step(self.state)
            self.state = y
            self.count += 1
            width = int(jnp.shape(y)[0])
            meta = (y.shape, y.dtype)
            host_tokens = np.zeros((width,), np.int32)
            return width, meta, host_tokens
'''

PRAGMA_LOOP = '''
    import jax
    import jax.numpy as jnp
    import numpy as np

    @jax.jit
    def model_step(x):
        return x + 1

    class Loop:
        def __init__(self):
            self.state = jnp.zeros((4,))

        def step(self):
            y = model_step(self.state)
            sanctioned = np.asarray(y)  # analysis: allow-hs002
            bad = np.asarray(y)
            return sanctioned, bad
'''

BAD_HAZARD = '''
    import functools
    import jax
    import jax.numpy as jnp
    import numpy as np

    @functools.partial(jax.jit, static_argnames=("width",))
    def forward(tokens, width):
        return tokens[:, :width]

    def serve(prompts):
        outs = []
        for p in prompts:
            fn = jax.jit(lambda x: x + 1)
            n = len(p)
            toks = np.zeros((1, n), np.int32)
            outs.append(forward(jnp.asarray(toks), width=n))
        return outs
'''

CLEAN_HAZARD = '''
    import functools
    import jax
    import jax.numpy as jnp
    import numpy as np

    @functools.partial(jax.jit, static_argnames=("width",))
    def forward(tokens, width):
        return tokens[:, :width]

    def prefill_bucket(n):
        w = 8
        while w < n:
            w *= 2
        return w

    def serve(prompts):
        outs = []
        for p in prompts:
            width = prefill_bucket(len(p))
            toks = np.zeros((1, width), np.int32)
            outs.append(forward(jnp.asarray(toks), width=width))
        return outs
'''

FIXTURE_ROOTS = ("pkg.loop.Loop.step",)


# ===========================================================================
# checker 1: host-sync
# ===========================================================================

def test_host_sync_flags_every_sync_family(tmp_path):
    project = make_project(tmp_path, loop=BAD_LOOP)
    findings = host_sync.check(project, roots=FIXTURE_ROOTS)
    rules = {f.rule for f in findings}
    assert rules == {"HS001", "HS002", "HS003", "HS004", "HS005"}
    assert all(f.path == "src/pkg/loop.py" for f in findings)
    assert all(f.symbol == "pkg.loop.Loop.step" for f in findings)


def test_host_sync_clean_loop_zero_false_positives(tmp_path):
    project = make_project(tmp_path, loop=CLEAN_LOOP)
    assert host_sync.check(project, roots=FIXTURE_ROOTS) == []


def test_host_sync_only_hot_path_is_checked(tmp_path):
    """The same sync outside the reachable set is not the hot path's
    problem — reachability, not a whole-tree grep."""
    project = make_project(tmp_path, loop=CLEAN_LOOP, offline=BAD_LOOP)
    assert host_sync.check(project, roots=FIXTURE_ROOTS) == []
    via_offline = host_sync.check(project, roots=("pkg.offline.Loop.step",))
    assert {f.rule for f in via_offline} == {"HS001", "HS002", "HS003",
                                            "HS004", "HS005"}


def test_host_sync_pragma_suppresses_sanctioned_line(tmp_path):
    project = make_project(tmp_path, loop=PRAGMA_LOOP)
    findings = host_sync.check(project, roots=FIXTURE_ROOTS)
    assert len(findings) == 1
    (f,) = findings
    assert f.rule == "HS002" and "bad = " not in f.snippet
    src = (tmp_path / "src/pkg/loop.py").read_text()
    bad_line = next(i for i, t in enumerate(src.splitlines(), 1)
                    if t.strip().startswith("bad ="))
    assert f.line == bad_line


# ===========================================================================
# checker 2: recompile hazards
# ===========================================================================

def test_recompile_flags_jit_in_body_and_shape_derived_args(tmp_path):
    project = make_project(tmp_path, hazard=BAD_HAZARD)
    findings = recompile.check(project)
    rules = {f.rule for f in findings}
    assert rules == {"RH001", "RH002", "RH003"}
    by_rule = {f.rule: f for f in findings}
    assert "jax.jit" in by_rule["RH001"].snippet
    assert "width" in by_rule["RH002"].message
    assert "tokens" in by_rule["RH003"].message


def test_recompile_bucketing_cleanses_shape_taint(tmp_path):
    """prefill_bucket(len(p)) is the sanctioned laundering of a runtime
    length into a small compile set — zero findings."""
    project = make_project(tmp_path, hazard=CLEAN_HAZARD)
    assert recompile.check(project) == []


def test_recompile_own_jit_decorator_not_flagged(tmp_path):
    """A module-scope @functools.partial(jax.jit, ...) decorator is the
    CORRECT idiom and must not self-flag as RH001."""
    project = make_project(tmp_path, hazard=CLEAN_HAZARD)
    assert not [f for f in recompile.check(project) if f.rule == "RH001"]


# ===========================================================================
# checker 3: Pallas launch contracts (handcrafted captures)
# ===========================================================================

class _Spec:
    """Minimal BlockSpec stand-in (block_shape + index_map attrs)."""

    def __init__(self, block_shape, index_map=None):
        self.block_shape = block_shape
        self.index_map = index_map


def _launch(**kw) -> CapturedLaunch:
    base = dict(
        label="fixture", kernel_path="fixture.py", kernel_name="k",
        grid=(2,), num_scalar_prefetch=0,
        in_specs=[_Spec((8, 128), lambda i: (i, 0))],
        out_specs=[_Spec((8, 128), lambda i: (i, 0))],
        in_shapes=[(16, 128)], out_shapes=[(16, 128)],
        prefetch_values=[], kernel_params=2, scratch_count=0)
    base.update(kw)
    return CapturedLaunch(**base)


def test_contract_good_launch_is_clean():
    assert check_launch(_launch()) == []


def test_contract_out_of_bounds_index_map():
    # grid walks 4 steps over a 2-block operand: blocks 2 and 3 read
    # past the buffer
    findings = check_launch(_launch(grid=(4,)))
    assert findings and {f.rule for f in findings} == {"PK004"}
    assert "out of bounds" in findings[0].message


def test_contract_operand_spec_arity_mismatch():
    findings = check_launch(_launch(in_shapes=[(16, 128), (4,)]))
    assert [f.rule for f in findings] == ["PK001"]


def test_contract_kernel_ref_count_mismatch():
    findings = check_launch(_launch(kernel_params=5))
    assert [f.rule for f in findings] == ["PK002"]


def test_contract_index_map_wrong_rank():
    bad = _Spec((8, 128), lambda i: (i,))
    findings = check_launch(_launch(in_specs=[bad]))
    assert findings and findings[0].rule == "PK003"


def test_contract_index_map_raise_is_pk003():
    def boom(i):
        raise ValueError("corrupt block table")
    findings = check_launch(_launch(in_specs=[_Spec((8, 128), boom)]))
    assert findings and findings[0].rule == "PK003"
    assert "ValueError" in findings[0].message


def test_contract_indivisible_block_is_pk005():
    findings = check_launch(_launch(
        in_specs=[_Spec((8, 128), lambda i: (min(i, 2), 0))],
        in_shapes=[(20, 128)]))
    assert [f.rule for f in findings] == ["PK005"]


def test_contract_prefetch_values_feed_index_maps():
    """Scalar-prefetch arrays are passed to index maps by VALUE — a
    map reading a real sequence length stays in bounds, one reading a
    corrupt length walks out."""
    lens_ok = [np.asarray([1], np.int32)]
    lens_bad = [np.asarray([9], np.int32)]
    out = _Spec((8, 128), lambda i, lens: (i, 0))
    spec = _Spec((8, 128), lambda i, lens: (min(int(lens[0]), 1) + i - i, 0))
    good = _launch(num_scalar_prefetch=1, prefetch_values=lens_ok,
                   in_specs=[spec], out_specs=[out], kernel_params=3)
    assert check_launch(good) == []
    raw = _Spec((8, 128), lambda i, lens: (int(lens[0]), 0))
    bad = _launch(num_scalar_prefetch=1, prefetch_values=lens_bad,
                  in_specs=[raw], out_specs=[out], kernel_params=3)
    assert [f.rule for f in check_launch(bad)] == ["PK004"]


# ===========================================================================
# checker 4: granularity drift
# ===========================================================================

_TILES = {"m_attn_decode": 64, "k_block": 128}


def test_drift_clean_when_all_three_agree():
    assert check_drift(dict(_TILES), declared=dict(_TILES),
                       launched=dict(_TILES)) == []


def test_drift_declared_vs_contract_is_gd001():
    declared = dict(_TILES, m_attn_decode=32)
    findings = check_drift(dict(_TILES), declared=declared,
                           launched=declared)
    assert [f.rule for f in findings] == ["GD001"]
    assert findings[0].symbol == "m_attn_decode"


def test_drift_launched_vs_declared_is_gd002():
    launched = dict(_TILES, k_block=256)
    findings = check_drift(dict(_TILES), declared=dict(_TILES),
                           launched=launched)
    assert [f.rule for f in findings] == ["GD002"]
    assert findings[0].symbol == "k_block"


def test_drift_unpinned_knob_is_gd003():
    findings = check_drift({}, declared=dict(_TILES),
                           launched=dict(_TILES))
    assert {f.rule for f in findings} == {"GD003"}
    assert len(findings) == len(_TILES)


def test_drift_findings_are_never_baseline_suppressible():
    findings = check_drift(dict(_TILES),
                           declared=dict(_TILES, m_attn_decode=32),
                           launched=dict(_TILES))
    bl = {"suppressions": {f.fingerprint: {"count": 99} for f in findings}}
    new, suppressed, _ = diff_against_baseline(findings, bl)
    assert new == findings and suppressed == []


# ===========================================================================
# baseline mechanics
# ===========================================================================

def _finding(line=3, snippet="int(y)"):
    return Finding("host-sync", "HS001", "src/pkg/loop.py", line,
                   "pkg.loop.Loop.step", "msg", snippet)


def test_fingerprint_is_line_number_independent():
    assert _finding(line=3).fingerprint == _finding(line=99).fingerprint
    assert (_finding(snippet="int(y)").fingerprint
            != _finding(snippet="int(z)").fingerprint)


def test_baseline_roundtrip_suppresses_known_debt(tmp_path):
    path = tmp_path / "analysis-baseline.json"
    write_baseline(path, [_finding()], {"m_attn_decode": 64})
    bl = load_baseline(path)
    assert bl["granularity_contract"] == {"m_attn_decode": 64}
    new, suppressed, stale = diff_against_baseline([_finding(line=7)], bl)
    assert new == [] and len(suppressed) == 1 and stale == []


def test_baseline_counts_gate_duplicate_snippets(tmp_path):
    path = tmp_path / "analysis-baseline.json"
    write_baseline(path, [_finding()], {})
    bl = load_baseline(path)
    new, suppressed, _ = diff_against_baseline(
        [_finding(line=3), _finding(line=9)], bl)
    assert len(suppressed) == 1 and len(new) == 1


def test_baseline_reports_stale_entries_when_debt_is_fixed(tmp_path):
    path = tmp_path / "analysis-baseline.json"
    write_baseline(path, [_finding()], {})
    _, _, stale = diff_against_baseline([], load_baseline(path))
    assert len(stale) == 1 and stale[0]["rule"] == "HS001"


# ===========================================================================
# CLI gate on fixture trees
# ===========================================================================

def _fixture_repo(tmp_path, code) -> Path:
    (tmp_path / "pyproject.toml").write_text("[project]\nname='fixture'\n")
    pkg = tmp_path / "src" / "pkg"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "loop.py").write_text(textwrap.dedent(code))
    return tmp_path


def test_cli_check_baseline_fails_on_bad_fixture_tree(tmp_path, capsys):
    root = _fixture_repo(tmp_path, BAD_LOOP)
    rc = main(["--root", str(root),
               "--checkers", "host-sync,recompile-hazard",
               "--roots", "pkg.loop.Loop.step", "--check-baseline"])
    assert rc == 2
    assert "FAIL" in capsys.readouterr().err


def test_cli_check_baseline_passes_on_clean_fixture_tree(tmp_path, capsys):
    root = _fixture_repo(tmp_path, CLEAN_LOOP)
    rc = main(["--root", str(root),
               "--checkers", "host-sync,recompile-hazard",
               "--roots", "pkg.loop.Loop.step", "--check-baseline"])
    assert rc == 0
    assert "0 new" in capsys.readouterr().out


def test_cli_json_output_is_machine_readable(tmp_path, capsys):
    root = _fixture_repo(tmp_path, BAD_LOOP)
    rc = main(["--root", str(root), "--checkers", "host-sync",
               "--roots", "pkg.loop.Loop.step", "--json"])
    assert rc == 0
    data = json.loads(capsys.readouterr().out)
    rules = {f["rule"] for f in data["findings"]}
    assert {"HS001", "HS002"} <= rules
    assert all(f["fingerprint"] for f in data["findings"])


# ===========================================================================
# the real tree vs. its committed baseline
# ===========================================================================

@pytest.fixture(scope="module")
def captures():
    return capture_launches()


@pytest.fixture(scope="module")
def tree_project():
    return Project(ROOT / "src", rel_to=ROOT)


def test_committed_baseline_is_current(captures):
    """`python -m repro.analysis --check-baseline` must pass on this
    tree: no NEW findings, no stale suppressions."""
    bl = load_baseline(ROOT / baseline_mod.BASELINE_NAME)
    findings = run_checkers(ROOT / "src", rel_to=ROOT,
                            contract=bl["granularity_contract"],
                            captures=captures)
    new, _, stale = diff_against_baseline(findings, bl)
    assert new == [], "\n".join(f.render() for f in new)
    assert stale == [], stale


def test_real_kernel_launches_satisfy_contracts(captures):
    assert len(captures) >= 6
    findings = pallas_contracts.check(captures=captures)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_launched_tiles_match_granularity_registry(captures):
    """The block shapes kernels ACTUALLY launch with are the numbers
    core.granularity hands the Eq. 12-14 predictor."""
    declared, launched = declared_tiles(), launched_tiles(captures)
    assert set(launched) == {"m_attn_decode", "m_moe_decode", "m_ssm",
                             "k_block"}
    for knob, got in launched.items():
        assert declared[knob] == got, knob


def test_committed_contract_pins_declared_tiles():
    bl = load_baseline(ROOT / baseline_mod.BASELINE_NAME)
    assert bl["granularity_contract"] == declared_tiles()


def test_one_sided_tile_change_fails_drift_check(captures):
    """Acceptance gate: halving a declared tile WITHOUT updating the
    pinned contract (or the kernels) must fail, un-suppressibly."""
    bl = load_baseline(ROOT / baseline_mod.BASELINE_NAME)
    declared = declared_tiles()
    declared["m_attn_decode"] //= 2
    findings = check_drift(bl["granularity_contract"], declared=declared,
                           launched=launched_tiles(captures))
    rules = {f.rule for f in findings}
    assert "GD001" in rules      # declared walked off the contract
    assert "GD002" in rules      # ...and off what kernels launch
    new, _, _ = diff_against_baseline(
        findings,
        {"suppressions": {f.fingerprint: {"count": 9} for f in findings}})
    assert new == findings


def test_serving_hot_path_has_no_unsanctioned_syncs(tree_project):
    """Satellite verification: after the host-mirror and on-device
    argmax fixes, the ONLY hot-path sync left is the known baselined
    diffusion per-row logits pull.  The admission argmax no longer
    appears: admission moved OUT of the step hot path (``admit`` runs
    at the arrival boundary, batching first-token readback), which
    drained its HS001 baseline entry."""
    findings = host_sync.check(tree_project)
    symbols = {f.symbol for f in findings}
    fixed = {
        "repro.serving.engine.DecodeEngine.decode_slots",
        "repro.serving.engine.DecodeEngine.commit_slots",
        "repro.serving.engine.DecodeEngine.prefill_slots",
        "repro.serving.scheduler.ServingLoop.step",
        "repro.serving.scheduler.ServingLoop.budget",
        "repro.serving.scheduler.ServingLoop._admit",
        "repro.serving.scheduler.ServingLoop.admit",
        "repro.serving.mtp.MTPSlotAdapter.run_step",
        "repro.serving.algorithm.GreedySlotAdapter.run_step",
    }
    assert not (symbols & fixed), sorted(symbols & fixed)
    assert symbols <= {
        "repro.serving.diffusion.DiffusionSlotAdapter.run_step",
    }, sorted(symbols)


# ===========================================================================
# compile discipline: steady-state serving never recompiles
# ===========================================================================

def test_steady_state_decode_zero_recompiles():
    """After warmup, more decode steps — including a mid-stream
    admission — must add ZERO entries to the decode jit cache (the
    regression the recompile-hazard checker guards statically)."""
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(50 + i), (5 + i,), 0, cfg.vocab_size))
        for i in range(4)]
    eng = DecodeEngine(cfg, params, batch=4, max_len=96)
    loop = ServingLoop(eng, mode="greedy")
    for p in prompts[:2]:
        loop.submit(p, 12)
    for _ in range(3):
        loop.admit()
        loop.step()
    warm = _decode_fn._cache_size()
    assert warm > 0
    for p in prompts[2:]:
        loop.submit(p, 12)
    while True:
        loop.admit()
        if not loop.step():
            break
    assert _decode_fn._cache_size() == warm
    assert len(loop.finished) == 4
