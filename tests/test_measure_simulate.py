"""Measurement protocol + latency simulator: the staircase mechanics."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (GranularitySpec, TPU_V5E, H20, LatencyCurve,
                        decode_forward_cost, extract_nmax, latency_curve,
                        predict_model, sensitivity_sweep,
                        staircase_boundaries)
from repro.core.simulate import (attention_core_cost, dense_ffn_cost,
                                 moe_ffn_cost, ssm_cost)


G = GranularitySpec.for_backend(n_experts=256)


class TestSimulatorStaircases:
    def test_attention_flops_staircase(self):
        """Physical attention FLOPs constant within a q tile, jump at the
        boundary (paper Fig. 3 RQ3)."""
        cfg = get_config("wedlm8b_like")
        f = [attention_core_cost(cfg, 1, n, 4096, G).flops
             for n in range(1, 130)]
        assert f[0] == f[63]                     # inside tile 1 (q_block=64)
        assert f[64] > f[63]                     # boundary crossing
        assert f[64] == f[127]                   # inside tile 2

    def test_dense_ffn_no_tile_staircase(self):
        """Dense FFN physical work scales ~linearly (mxu sublane only)."""
        cfg = get_config("wedlm8b_like")
        f = [dense_ffn_cost(cfg, 1, n).flops for n in (16, 32, 64)]
        assert f[1] == 2 * f[0] and f[2] == 2 * f[1]

    def test_moe_padded_flops_staircase(self):
        """Balanced MoE: physical FLOPs flat once all experts are active
        (the paper's Eq. 26 baseline exists precisely because activation
        growth below N_bal0 is not a parallelism effect)."""
        cfg = get_config("llada_mini_like")      # E=256 k=8, N_bal0=32
        f = [moe_ffn_cost(cfg, 1, n, G, "balanced").flops
             for n in range(1, 130)]
        # after N_bal0: every expert holds 1..16 tokens -> one 16-block
        assert len(set(f[31:128])) == 1          # flat padded region
        # below N_bal0: activation-growth regime (linear in N)
        assert f[0] < f[15] < f[31]

    def test_moe_skewed_padding_smaller_capacity(self):
        cfg = get_config("llada_mini_like")
        bal = moe_ffn_cost(cfg, 1, 32, G, "balanced")
        skew = moe_ffn_cost(cfg, 1, 32, G, "skewed")
        # skewed concentrates tokens: fewer active experts -> less weight
        # traffic but same-or-more padding per expert
        assert skew.bytes < bal.bytes

    def test_ssm_chunk_staircase(self):
        cfg = get_config("falcon_mamba_7b")
        f = [ssm_cost(cfg, 1, n, G).flops for n in range(1, 35)]
        assert f[0] == f[15]                     # chunk = 16
        assert f[16] > f[15]

    def test_logical_vs_physical_flops(self):
        cfg = get_config("wedlm8b_like")
        c = decode_forward_cost(cfg, 1, 1, 4096, G)
        assert c.flops >= c.logical_flops        # padding never shrinks work


class TestSimulatedNFP:
    def test_dense_model_nfp_matches_principle(self):
        """Model-level validation, TPU edition (paper Fig. 4).

        In the dense-idle-limited regime (b >= 4 on TPU v5e) the simulated
        boundary matches the closed form tightly.  In the attn-tile regime
        (b=1) the min-composition is a CONSERVATIVE bound on TPU v5e: the
        tile jump is diluted by model-wide weight traffic (EXPERIMENTS.md
        §Model-level) — so measured >= principle there."""
        cfg = get_config("wedlm8b_like")
        ns = list(range(1, 513))
        for b in (4, 8):
            pred = predict_model(cfg, TPU_V5E, G, b=b, ell=512)
            pts = latency_curve(cfg, TPU_V5E, b, 512, ns)
            curve = LatencyCurve([n for n, _ in pts], [t for _, t in pts])
            measured = extract_nmax(curve, eps=0.2)
            assert 0.7 * pred.n_max <= measured <= 1.4 * pred.n_max
        pred1 = predict_model(cfg, TPU_V5E, G, b=1, ell=512)
        pts = latency_curve(cfg, TPU_V5E, 1, 512, ns)
        curve = LatencyCurve([n for n, _ in pts], [t for _, t in pts])
        assert extract_nmax(curve, eps=0.2) >= pred1.n_max

    def test_batch_shrinks_measured_boundary(self):
        cfg = get_config("wedlm8b_like")
        ns = [1, 2, 4, 8, 16, 32, 64, 128, 256]
        bounds = []
        for b in (1, 8, 32):
            c = LatencyCurve(*zip(*latency_curve(cfg, TPU_V5E, b, 512, ns)))
            bounds.append(extract_nmax(c, 0.2))
        assert bounds[0] >= bounds[1] >= bounds[2]

    def test_sensitivity_sweep_monotone(self):
        cfg = get_config("wedlm8b_like")
        ns = list(range(1, 257))
        c = LatencyCurve(*zip(*latency_curve(cfg, TPU_V5E, 1, 512, ns)))
        sweep = sensitivity_sweep(c)
        vals = [sweep[e] for e in sorted(sweep)]
        assert all(a <= b for a, b in zip(vals, vals[1:]))

    def test_staircase_detector(self):
        ns = list(range(1, 10))
        vals = [1, 1, 1, 2, 2, 2, 3, 3, 3]
        assert staircase_boundaries(ns, vals) == [4, 7]

    def test_limiting_module_shift_with_context(self):
        """Paper Sec 5.2: short context -> MoE-limited; the attention term
        grows with L in the simulator's module times."""
        cfg = get_config("llada_mini_like")
        short = decode_forward_cost(cfg, 1, 64, 256, G)
        long_ = decode_forward_cost(cfg, 1, 64, 32768, G)
        t_attn_short = [m.time(TPU_V5E) for m in short.modules
                        if m.name == "attn_core"][0]
        t_attn_long = [m.time(TPU_V5E) for m in long_.modules
                       if m.name == "attn_core"][0]
        assert t_attn_long > 10 * t_attn_short


class TestMeasureProtocol:
    def test_extract_nmax_missing_baseline_clear_error(self):
        """A curve that never sampled its baseline must fail loudly, not
        with list.index's opaque ValueError."""
        curve = LatencyCurve([2, 4, 8], [1.0, 1.0, 1.0], baseline_n=1)
        with pytest.raises(ValueError, match="baseline_n=1 was not sampled"):
            extract_nmax(curve, 0.2)

    def test_contiguous_mode_stops_at_first_violation(self):
        """A noisy rebound past the knee cannot inflate N_max in
        contiguous mode (the calibrator's setting)."""
        curve = LatencyCurve([1, 2, 3, 4], [1.0, 1.5, 1.05, 2.0])
        assert extract_nmax(curve, 0.2) == 3            # rebound wins
        assert extract_nmax(curve, 0.2, contiguous=True) == 1

    def test_contiguous_equals_default_on_monotone_curves(self):
        curve = LatencyCurve(list(range(1, 9)),
                             [1.0, 1.0, 1.1, 1.15, 1.3, 1.5, 2.0, 3.0])
        assert (extract_nmax(curve, 0.2)
                == extract_nmax(curve, 0.2, contiguous=True) == 4)

    def test_time_callable_returns_median_and_spread(self):
        from repro.core import time_callable
        med, spread = time_callable(lambda: sum(range(200)),
                                    warmup=1, rounds=3, iters=3)
        assert med > 0.0
        assert spread >= 0.0

    def test_sweep_callable_carries_spreads(self):
        from repro.core import sweep_callable
        curve = sweep_callable(lambda n: (lambda: sum(range(n))),
                               [1, 2, 4], warmup=0, rounds=2, iters=2)
        assert len(curve.spreads) == len(curve.ns) == 3
        assert curve.max_spread >= 0.0


@given(n=st.integers(1, 256), b=st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_costs_are_positive_and_monotone_in_n(n, b):
    cfg = get_config("wedlm8b_like")
    c1 = decode_forward_cost(cfg, b, n, 1024, G)
    c2 = decode_forward_cost(cfg, b, n + 64, 1024, G)
    assert c1.flops > 0 and c1.bytes > 0
    assert c2.flops >= c1.flops
    assert c2.time(TPU_V5E) >= c1.time(TPU_V5E) - 1e-12
