"""Trace-driven load harness: preemption byte-equivalence goldens (all
four serve modes, dense + paged, kernel on/off), trace-generator
property tests, latency-stat hand fixtures, admission-control policy
tests, and the BENCH_serving.json schema pin.

The golden contract: a request that gets preempted mid-stream (its KV
evicted, recomputed on resume) must emit the byte-identical token
stream of a never-preempted run — the stream and the pending token are
host state, so eviction must be invisible in the output.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.loadgen import (ArrivalSpec, LengthSpec, RequestRecord,
                           TenantSpec, Trace, TraceSpec, generate_trace,
                           itls, percentile, pinned_spec, replay_trace,
                           summarize, ttft)
from repro.models import init_model
from repro.serving import (DEFAULT_SLO_CLASSES, AdmissionConfig,
                           AdmissionRejected, DecodeEngine, PagedKVConfig,
                           ServingLoop, init_mtp_heads)

MAX_LEN = 256
TOKENS = 8
MODES = ("greedy", "speculative", "mtp", "diffusion")


@pytest.fixture(scope="module")
def model():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _loop(cfg, params, mode, *, slots=2, paged=None, use_kernel=False,
          admission=None, step_clock=None, max_len=MAX_LEN):
    eng = DecodeEngine(cfg, params, batch=slots, max_len=max_len,
                       use_kernel=use_kernel, paged=paged)
    kwargs = {}
    if mode == "mtp":
        kwargs["mtp_heads"] = init_mtp_heads(
            jax.random.PRNGKey(5), cfg.d_model, cfg.vocab_size, n_heads=4)
    if mode == "diffusion":
        # diffusion's stream depends on the block partition, so the
        # goldens pin it: preempted and baseline runs must refine the
        # same blocks
        kwargs.update(block_size=3, refine_steps=2)
    return ServingLoop(eng, mode=mode, admission=admission,
                       step_clock=step_clock, **kwargs)


def _prompts(cfg, n, seed=3, lo=4, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=int(rng.integers(lo, hi)))
            for _ in range(n)]


def _drive(loop, prompts, tokens=TOKENS, preempt_at=None):
    """Manual serve loop; optionally force-evict the lowest active slot
    after ``preempt_at`` decode steps (mid-stream: the victim has
    emitted tokens and still owes more)."""
    for p in prompts:
        loop.submit(p, tokens)
    steps = 0
    while True:
        loop.admit()
        if preempt_at is not None and steps == preempt_at and loop.active:
            victim = loop.active[min(loop.active)]
            assert 0 < len(victim.generated) < victim.max_tokens
            loop.preempt(min(loop.active))
            loop.admit()
        if not loop.step():
            break
        steps += 1
    return {rid: req.tokens() for rid, req in sorted(loop.finished.items())}


# ===========================================================================
# Preemption byte-equivalence goldens
# ===========================================================================


def _golden(cfg, params, mode, *, paged=None, use_kernel=False, slots=2):
    prompts = _prompts(cfg, 3)
    base = _drive(_loop(cfg, params, mode, slots=slots, paged=paged,
                        use_kernel=use_kernel), prompts)
    loop = _loop(cfg, params, mode, slots=slots, paged=paged,
                 use_kernel=use_kernel)
    out = _drive(loop, prompts, preempt_at=2)
    assert loop.preempted_total >= 1
    assert loop.resumed_total >= 1
    if paged is not None:
        assert loop.stats()["kv_preemptions"] >= 1
    assert base.keys() == out.keys()
    for rid in base:
        assert np.array_equal(base[rid], out[rid]), f"req {rid} diverged"


@pytest.mark.slow
@pytest.mark.parametrize("paged", [None, PagedKVConfig(block_size=16)],
                         ids=["dense", "paged"])
@pytest.mark.parametrize("mode", MODES)
def test_preemption_golden_xla(model, mode, paged):
    """Evict + recompute-on-resume is stream-invisible in every serve
    mode on the XLA path, dense and paged."""
    cfg, params = model
    _golden(cfg, params, mode, paged=paged)


@pytest.mark.slow
@pytest.mark.parametrize("paged", [None, PagedKVConfig(block_size=128)],
                         ids=["dense", "paged"])
@pytest.mark.parametrize("mode", ["greedy", "speculative"])
def test_preemption_golden_kernel(model, mode, paged):
    """Same contract through the Pallas kernel path (paged pins
    block_size = K_BLOCK, as in test_paged_kv)."""
    cfg, params = model
    _golden(cfg, params, mode, paged=paged, use_kernel=True)


def test_preemption_golden_fast(model):
    """Tier-1 smoke of the golden contract (greedy + small pages)."""
    cfg, params = model
    _golden(cfg, params, "greedy", paged=PagedKVConfig(block_size=16))


def test_policy_preemption_under_tiny_pool(model):
    """Policy-driven eviction: a tiny block pool + a higher-priority
    arrival preempts the batch-class resident, and both streams still
    match their solo references."""
    cfg, params = model
    rng = np.random.default_rng(9)
    # fixed 12-token prompts: each reservation (12 + 8 tokens) costs
    # exactly 2 of the pool's 3 blocks, so the second admission MUST
    # evict the first
    prompts = [rng.integers(0, cfg.vocab_size, size=12) for _ in range(2)]
    refs = []
    for p in prompts:
        eng = DecodeEngine(cfg, params, batch=1, max_len=MAX_LEN)
        refs.append(np.asarray(eng.greedy_generate(
            np.asarray(p)[None], TOKENS)[0]))
    # pool covers ~one resident's reservation: the interactive arrival
    # cannot fit until the batch request's blocks are evicted
    loop = _loop(cfg, params, "greedy", slots=2,
                 paged=PagedKVConfig(block_size=16, n_blocks=3),
                 admission=AdmissionConfig(preemption=True))
    loop.submit(prompts[0], TOKENS, slo_class="batch")
    loop.admit()
    loop.step()
    loop.submit(prompts[1], TOKENS, slo_class="interactive")
    loop.admit()
    assert loop.preempted_total == 1
    active_classes = {r.slo_class for r in loop.active.values()}
    assert "interactive" in active_classes
    victim = next(iter(loop.waiting))
    assert victim.slo_class == "batch" and victim.preemptions == 1
    while True:
        loop.admit()
        if not loop.step():
            break
    out = {rid: r.tokens() for rid, r in loop.finished.items()}
    assert np.array_equal(out[0], refs[0])
    assert np.array_equal(out[1], refs[1])
    assert loop.resumed_total == 1


# ===========================================================================
# Admission-control policies
# ===========================================================================


def test_backpressure_rejects_beyond_max_waiting(model):
    cfg, params = model
    loop = _loop(cfg, params, "greedy",
                 admission=AdmissionConfig(max_waiting=2))
    loop.submit(_prompts(cfg, 1)[0], 4)
    loop.submit(_prompts(cfg, 1)[0], 4)
    with pytest.raises(AdmissionRejected):
        loop.submit(_prompts(cfg, 1)[0], 4)
    assert loop.rejected_total == 1
    assert len(loop.waiting) == 2


def test_admission_order_is_slo_priority(model):
    """A later-arriving interactive request admits before an earlier
    batch request when only one slot is free (FIFO within a class)."""
    cfg, params = model
    loop = _loop(cfg, params, "greedy", slots=1)
    p = _prompts(cfg, 3, seed=7)
    batch_req = loop.submit(p[0], 4, slo_class="batch")
    inter_req = loop.submit(p[1], 4, slo_class="interactive")
    loop.admit()
    assert [r.rid for r in loop.active.values()] == [inter_req.rid]
    assert [r.rid for r in loop.waiting] == [batch_req.rid]


def test_unknown_slo_class_rejected_at_submit(model):
    cfg, params = model
    loop = _loop(cfg, params, "greedy")
    with pytest.raises(ValueError, match="unknown SLO class"):
        loop.submit(_prompts(cfg, 1)[0], 4, slo_class="platinum")


# ===========================================================================
# Trace generator properties (hypothesis; shim-compatible strategies)
# ===========================================================================


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=9999))
def test_trace_same_seed_byte_identical(seed):
    spec = TraceSpec(
        seed=seed, n_requests=20, vocab_size=64,
        arrivals=ArrivalSpec(kind="mmpp"),
        tenants=(TenantSpec("a", slo_class="interactive", weight=1.0),
                 TenantSpec("b", slo_class="batch", weight=2.0,
                            shared_prefix_len=6, share_prob=0.5)))
    a, b = generate_trace(spec), generate_trace(spec)
    assert a.to_json() == b.to_json()
    assert a.fingerprint() == b.fingerprint()
    rt = Trace.from_json(a.to_json())
    assert rt.to_json() == a.to_json()
    assert rt.fingerprint() == a.fingerprint()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=99),
       rate=st.sampled_from([5.0, 40.0]))
def test_poisson_empirical_rate(seed, rate):
    n = 600
    spec = TraceSpec(seed=seed, n_requests=n,
                     arrivals=ArrivalSpec(kind="poisson", rate_rps=rate))
    tr = generate_trace(spec)
    arrivals = [r.arrival_s for r in tr.requests]
    assert arrivals == sorted(arrivals)
    empirical = n / arrivals[-1]
    assert 0.75 * rate <= empirical <= 1.25 * rate


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=99))
def test_mmpp_rate_between_calm_and_burst(seed):
    spec = TraceSpec(seed=seed, n_requests=600,
                     arrivals=ArrivalSpec(kind="mmpp", rate_rps=10.0,
                                          burst_rate_rps=40.0))
    tr = generate_trace(spec)
    empirical = len(tr.requests) / tr.requests[-1].arrival_s
    assert 0.9 * 10.0 <= empirical <= 1.1 * 40.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=99),
       dist=st.sampled_from(["pareto", "lognormal"]))
def test_length_mix_heavy_tail_quantiles(seed, dist):
    lo, hi = 4, 64
    spec = TraceSpec(
        seed=seed, n_requests=500,
        prompt_lens=LengthSpec(dist=dist, lo=lo, hi=hi, alpha=1.1,
                               mu=2.0, sigma=0.8))
    lens = [len(r.prompt) for r in generate_trace(spec).requests]
    assert min(lens) >= lo and max(lens) <= hi
    med = percentile(lens, 50)
    mean = sum(lens) / len(lens)
    # heavy-tail signature: mass near lo, skew pulls the mean right
    assert med <= 16
    assert mean > med
    assert percentile(lens, 99) >= 16


def test_shared_prefix_fleet_structure():
    spec = TraceSpec(
        seed=5, n_requests=12, vocab_size=64,
        prompt_lens=LengthSpec(dist="fixed", lo=24, hi=24),
        tenants=(TenantSpec("fleet", shared_prefix_len=16,
                            share_prob=1.0),))
    tr = generate_trace(spec)
    heads = {r.prompt[:16] for r in tr.requests}
    tails = {r.prompt[16:] for r in tr.requests}
    assert len(heads) == 1          # every prompt shares the prefix
    assert len(tails) > 1           # but streams stay distinct


def test_trace_spec_validation():
    with pytest.raises(ValueError, match="arrival kind"):
        generate_trace(TraceSpec(arrivals=ArrivalSpec(kind="weibull")))
    with pytest.raises(ValueError, match="length dist"):
        generate_trace(TraceSpec(prompt_lens=LengthSpec(dist="zipf")))
    with pytest.raises(ValueError, match="lo=9 > hi"):
        generate_trace(TraceSpec(prompt_lens=LengthSpec(lo=9, hi=4)))
    with pytest.raises(ValueError, match="at least one tenant"):
        generate_trace(TraceSpec(tenants=()))


# ===========================================================================
# Latency-stat math vs hand-computed fixtures
# ===========================================================================


def test_percentile_nearest_rank_vs_linear():
    xs = [3, 1, 2, 4]                     # unsorted on purpose
    assert percentile(xs, 50) == 2        # ceil(0.5*4)=2nd order stat
    assert percentile(xs, 50, "linear") == 2.5
    assert percentile(xs, 95) == 4        # ceil(3.8)=4th
    assert percentile(xs, 95, "linear") == pytest.approx(3.85)
    assert percentile(xs, 0) == 1
    assert percentile(xs, 100) == 4
    assert percentile(xs, 100, "linear") == 4


def test_percentile_guards():
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50)
    assert percentile([7.0], 99) == 7.0
    assert percentile([7.0], 1, "linear") == 7.0
    with pytest.raises(ValueError, match="outside"):
        percentile([1.0], 101)
    with pytest.raises(ValueError, match="unknown percentile method"):
        percentile([1.0, 2.0], 50, "cubic")


def test_summarize_hand_fixture():
    a = RequestRecord(rid=0, slo_class="interactive", arrival_s=0.0,
                      token_times=[0.1, 0.14, 0.18])       # meets SLO
    b = RequestRecord(rid=1, slo_class="interactive", arrival_s=0.0,
                      token_times=[0.9, 1.0])              # TTFT misses
    c = RequestRecord(rid=2, slo_class="batch", rejected=True)
    out = summarize([a, b, c], DEFAULT_SLO_CLASSES, makespan_s=2.0)
    assert out["requests"] == 3
    assert out["completed"] == 2
    assert out["rejected"] == 1
    assert out["tokens"] == 5
    assert out["throughput_tok_s"] == pytest.approx(2.5)
    assert out["goodput_tok_s"] == pytest.approx(1.5)      # only rec a
    assert out["slo_attainment"] == pytest.approx(0.5)
    assert ttft(a) == pytest.approx(0.1)
    assert itls(a) == pytest.approx([0.04, 0.04])
    assert out["ttft_p50_s"] == pytest.approx(0.1)
    assert out["ttft_p95_s"] == pytest.approx(0.9)
    assert out["itl_p50_s"] == pytest.approx(0.04)
    batch = out["per_class"]["batch"]
    assert batch["completed"] == 0
    assert batch["slo_attainment"] is None
    assert batch["goodput_tok_s"] == 0.0


def test_summarize_single_token_stream_scored_on_ttft_alone():
    r = RequestRecord(rid=0, slo_class="interactive", arrival_s=0.0,
                      token_times=[0.2])
    out = summarize([r], DEFAULT_SLO_CLASSES, makespan_s=1.0)
    assert out["slo_attainment"] == 1.0    # no ITL sample: TTFT decides
    assert out["itl_p50_s"] is None


# ===========================================================================
# Replay harness (real ServingLoop, virtual clock)
# ===========================================================================


def _toy_clock(width, ell):
    return 1e-3 * width * (1.0 + ell / 256.0)


def _fleet_spec(n=5):
    return TraceSpec(
        seed=11, n_requests=n, vocab_size=64,
        arrivals=ArrivalSpec(kind="poisson", rate_rps=100.0),
        prompt_lens=LengthSpec(dist="fixed", lo=24, hi=24),
        output_lens=LengthSpec(dist="fixed", lo=3, hi=3),
        tenants=(TenantSpec("fleet", shared_prefix_len=16,
                            share_prob=1.0),))


def test_replay_fleet_hits_prefix_cache(model):
    """Shared-prefix fleet traffic reuses cached prefix blocks when
    replayed through a paged engine (block 16 == prefix len)."""
    cfg, params = model
    loop = _loop(cfg, params, "greedy",
                 paged=PagedKVConfig(block_size=16),
                 step_clock=_toy_clock)
    rep = replay_trace(loop, generate_trace(_fleet_spec()))
    assert rep["serving"]["prefill_positions_saved"] > 0
    assert rep["serving"]["prefix_hits"] > 0
    assert rep["metrics"]["completed"] == 5


def test_replay_same_seed_metrics_identical(model):
    """The determinism gate at test scale: two fresh replays of the
    same trace on the simulated clock produce identical metrics."""
    cfg, params = model
    tr = generate_trace(_fleet_spec())
    reps = []
    for _ in range(2):
        loop = _loop(cfg, params, "greedy",
                     paged=PagedKVConfig(block_size=16),
                     step_clock=_toy_clock)
        reps.append(replay_trace(loop, tr))
    assert reps[0]["metrics"] == reps[1]["metrics"]
    assert reps[0]["makespan_s"] == reps[1]["makespan_s"]
    assert reps[0]["clock"] == "simulated"


def test_replay_backpressure_rejections_accounted(model):
    """A near-simultaneous burst against one slot + a one-deep queue:
    rejections surface in the records, the metrics, and the loop."""
    cfg, params = model
    spec = TraceSpec(
        seed=3, n_requests=6, vocab_size=64,
        arrivals=ArrivalSpec(kind="poisson", rate_rps=1e6),
        prompt_lens=LengthSpec(dist="fixed", lo=6, hi=6),
        output_lens=LengthSpec(dist="fixed", lo=2, hi=2))
    loop = _loop(cfg, params, "greedy", slots=1,
                 admission=AdmissionConfig(max_waiting=1),
                 step_clock=_toy_clock)
    rep = replay_trace(loop, generate_trace(spec))
    m = rep["metrics"]
    assert m["rejected"] > 0
    assert m["rejected"] == loop.rejected_total
    assert m["completed"] + m["rejected"] == 6
    assert sum(r.rejected for r in rep["records"]) == m["rejected"]


def test_replay_ttft_includes_queue_wait(model):
    """Two same-length requests, one slot: the queued request's TTFT
    must include its wait for the resident to finish."""
    cfg, params = model
    spec = TraceSpec(
        seed=4, n_requests=2, vocab_size=64,
        arrivals=ArrivalSpec(kind="poisson", rate_rps=1e6),
        prompt_lens=LengthSpec(dist="fixed", lo=6, hi=6),
        output_lens=LengthSpec(dist="fixed", lo=4, hi=4))
    loop = _loop(cfg, params, "greedy", slots=1, step_clock=_toy_clock)
    rep = replay_trace(loop, generate_trace(spec))
    ttfts = sorted(ttft(r) for r in rep["records"])
    assert ttfts[1] > ttfts[0]


# ===========================================================================
# BENCH_serving.json schema + pin
# ===========================================================================

_BENCH = Path(__file__).resolve().parents[1] / "BENCH_serving.json"

METRIC_KEYS = ("requests", "completed", "rejected", "preemptions",
               "tokens", "throughput_tok_s", "goodput_tok_s",
               "slo_attainment", "ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
               "itl_p50_s", "itl_p95_s", "itl_p99_s", "per_class")
SERVING_KEYS = ("requests", "tokens", "forwards", "preemptions",
                "resumes", "rejections", "prefill_positions_saved",
                "kv_preemptions")
PINNED_KEYS = ("arch", "mode", "slots", "max_len", "kv_block_size",
               "kv_blocks", "max_waiting", "preemption", "eps",
               "trace_seed", "trace_requests")


def test_bench_serving_schema():
    """The committed per-PR scorecard parses, carries the full schema,
    and its trace fingerprint regenerates from the pinned spec."""
    data = json.loads(_BENCH.read_text())
    assert data["schema_version"] == 1
    assert data["bench"] == "serving_load_harness"
    assert data["clock"] == "simulated"
    for k in PINNED_KEYS:
        assert k in data["pinned"], k
    for k in METRIC_KEYS:
        assert k in data["metrics"], k
    for k in SERVING_KEYS:
        assert k in data["serving"], k
    m = data["metrics"]
    assert m["completed"] + m["rejected"] == data["pinned"]["trace_requests"]
    assert 0.0 <= m["slo_attainment"] <= 1.0
    assert m["goodput_tok_s"] <= m["throughput_tok_s"] + 1e-9
    assert data["makespan_s"] > 0
    # the fingerprint pins the exact pinned-trace bytes
    spec = pinned_spec(seed=data["pinned"]["trace_seed"],
                       n_requests=data["pinned"]["trace_requests"])
    assert generate_trace(spec).fingerprint() == data["trace_fingerprint"]
