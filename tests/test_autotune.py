"""repro.autotune: calibration sweeps, artifact store, and the AIMD
budget controller — plus the scheduler integration contracts (golden
greedy byte-equality, controlled-vs-static latency under a simulated
clock)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.autotune import (BudgetController, CalibrationMismatchError,
                            ControllerConfig, calibrate_specs, load_table,
                            save_table, width_grid)
from repro.configs import get_config
from repro.core import GranularitySpec, TPU_V5E
from repro.core.simulate import decode_forward_cost

EPS = 0.2
SLOTS = 4
BUCKETS = (256, 1024, 4096)


def _gran(cfg):
    return GranularitySpec.for_backend(
        cfg.ffn.n_experts,
        head_dim=(cfg.attention.head_dim if cfg.attention else 128))


def _table(arch, modes=("speculative",), batch=SLOTS, buckets=BUCKETS):
    cfg = get_config(arch)
    return cfg, calibrate_specs(cfg, TPU_V5E, _gran(cfg), batch=batch,
                                modes=modes, eps=EPS, buckets=buckets)


# ===========================================================================
# Calibration sweeps
# ===========================================================================

class TestCalibrate:
    def test_entries_cover_modes_buckets(self):
        _, t = _table("stablelm_3b", modes=("greedy", "mtp"))
        assert {(e.mode, e.ell) for e in t.entries} == {
            (m, b) for m in ("greedy", "mtp") for b in BUCKETS}

    def test_calibrated_budget_clamped_to_analytic(self):
        """Calibration only refines DOWNWARD: over-prediction >= 1 on
        every entry, and the budget never leaves [1, analytic]."""
        for arch in ("stablelm_3b", "granite_moe_3b_a800m",
                     "falcon_mamba_7b", "mixtral_8x22b"):
            _, t = _table(arch)
            for e in t.entries:
                assert 1 <= e.calibrated_budget <= e.analytic_nmax, arch
                assert e.overprediction >= 1.0, arch

    def test_moe_overpredicts(self):
        """The headline: on the balanced-MoE config the analytic budget
        (tau-limited) over-predicts the measured serve-time knee —
        widening past width 1 activates experts the width-1 baseline
        never paid for."""
        _, t = _table("granite_moe_3b_a800m")
        overs = [e.overprediction for e in t.entries]
        assert max(overs) > 1.0
        # the idle-compute intuition over-predicts even harder (Table 24)
        assert all(e.idle_overprediction >= e.overprediction
                   for e in t.entries)

    def test_knee_matches_curve_tolerance(self):
        """Every width at or below the knee that was sampled satisfies
        the (1+eps) tolerance against the width-1 baseline."""
        _, t = _table("granite_moe_3b_a800m")
        for e in t.entries:
            t0 = e.times[e.ns.index(1)]
            for n, tn in zip(e.ns, e.times):
                if n <= e.measured_nmax:
                    assert tn <= (1 + EPS) * t0 + 1e-15

    def test_width_grid_covers_small_widths(self):
        ns = width_grid()
        assert set(range(1, 9)) <= set(ns)
        assert 65 in ns and 17 in ns          # one-past-tile probes


# ===========================================================================
# Artifact store
# ===========================================================================

class TestStore:
    def test_roundtrip_identical_budgets(self, tmp_path):
        _, t = _table("granite_moe_3b_a800m",
                      modes=("greedy", "speculative"))
        path = str(tmp_path / "calib.json")
        save_table(t, path)
        t2 = load_table(path, expect_key=t.key)
        assert t2.key == t.key and len(t2.entries) == len(t.entries)
        for mode in ("greedy", "speculative"):
            for ell in (1, 200, 256, 1000, 5000):
                assert (t.budget(mode, ell, False)
                        == t2.budget(mode, ell, False))
        # full numeric round-trip, not just the derived budgets
        for a, b in zip(t.entries, t2.entries):
            assert a == b

    def test_stale_key_refuses_with_clear_error(self, tmp_path):
        _, t = _table("stablelm_3b")
        path = str(tmp_path / "calib.json")
        save_table(t, path)
        with pytest.raises(CalibrationMismatchError, match="stale"):
            load_table(path, expect_key="0000000000000000")
        # loading without an expectation still works (inspection tools)
        assert load_table(path).key == t.key

    def test_key_depends_on_spec(self):
        _, t_a = _table("stablelm_3b")
        _, t_b = _table("granite_moe_3b_a800m")
        _, t_c = _table("stablelm_3b", batch=2)
        assert len({t_a.key, t_b.key, t_c.key}) == 3

    def test_schema_version_refuses(self, tmp_path):
        import json
        _, t = _table("stablelm_3b")
        path = str(tmp_path / "calib.json")
        save_table(t, path)
        data = json.load(open(path))
        data["schema"] = 999
        json.dump(data, open(path, "w"))
        with pytest.raises(CalibrationMismatchError, match="schema"):
            load_table(path)

    def test_bucket_lookup_is_conservative(self):
        _, t = _table("granite_moe_3b_a800m")
        # smallest bucket >= ell; past the last bucket, the largest
        assert t.lookup("speculative", 100, False).ell == 256
        assert t.lookup("speculative", 257, False).ell == 1024
        assert t.lookup("speculative", 10**6, False).ell == 4096
        # unknown mode falls back to any calibrated mode (the decode
        # forward is mode-independent)
        assert t.lookup("greedy", 100, False) is not None


# ===========================================================================
# BudgetController
# ===========================================================================

class TestController:
    def _controller(self, table=None, **kw):
        cfg = ControllerConfig(eps=EPS, **kw)
        c = BudgetController(table=table, config=cfg,
                             mode="speculative", use_kernel=False)
        return c

    def test_warmup_serves_width_one(self):
        c = self._controller()
        assert c.budget(100, 4, 40) == 4         # no baseline yet
        c.observe(100, 1, 1.0)
        assert c.budget(100, 4, 40) >= 4         # baseline exists now

    @given(n_active=st.integers(1, 16), analytic=st.integers(1, 256),
           lat=st.floats(0.1, 10.0), width=st.integers(1, 32))
    @settings(max_examples=100, deadline=None)
    def test_budget_always_in_bounds(self, n_active, analytic, lat, width):
        """Invariant: whatever was observed, the returned budget stays
        in [1, max(analytic, n_active)]."""
        c = self._controller()
        for i in range(4):
            b = c.budget(100, n_active, analytic)
            assert 1 <= b <= max(analytic, n_active)
            c.observe(100, width if i % 2 else 1, lat * (1 + i))

    def test_never_probes_within_cooldown(self):
        c = self._controller(patience=1, cooldown=5)
        c.observe(100, 1, 1.0)                   # baseline
        c.budget(100, 1, 64)
        # drive the width up, then force a shrink
        for _ in range(6):
            c.budget(100, 1, 64)
            c.observe(100, 1, 1.0)
        st_ = c._states[c._bucket(100)]
        assert st_.width > 1
        c.observe(100, st_.width, 100.0)         # violation -> shrink
        shrunk = st_.width
        assert st_.cooldown == 5 and st_.shrinks == 1
        # the next cooldown-1 clean steps must not probe up
        for _ in range(4):
            c.budget(100, 1, 64)
            c.observe(100, 1, 1.0)
            assert st_.width <= shrunk
        # after the window closes, probing resumes
        c.observe(100, 1, 1.0)
        assert st_.width == shrunk + 1

    def test_variance_gate_absorbs_single_spike(self):
        """patience=2: one noisy spike is gated, the width holds; a
        SECOND consecutive violation shrinks."""
        c = self._controller(patience=2)
        c.observe(100, 1, 1.0)
        for _ in range(5):
            c.budget(100, 1, 64)
            c.observe(100, 1, 1.0)
        st_ = c._states[c._bucket(100)]
        w0 = st_.width
        c.observe(100, w0, 50.0)                 # spike
        assert st_.width == w0 and st_.gated == 1 and st_.shrinks == 0
        c.observe(100, w0, 1.0)                  # clean -> streak resets
        c.observe(100, w0, 50.0)                 # spike again (isolated)
        assert st_.shrinks == 0
        c.observe(100, w0, 50.0)                 # second consecutive
        assert st_.shrinks == 1 and st_.width < w0

    def test_converges_to_stationary_width_with_table(self):
        """With a calibrated cap and in-tolerance latencies, the width
        climbs to the cap and then stays put — a stationary latency
        profile, no sawtooth."""
        _, t = _table("stablelm_3b")
        entry = t.lookup("speculative", 256, False)
        c = self._controller(table=t)
        base = entry.baseline_time
        widths = []
        for _ in range(40):
            b = c.budget(256, 1, entry.analytic_nmax)
            c.observe(256, b, base * (1 + 0.001 * b) if b > 1 else base)
            widths.append(b)
        cap = entry.calibrated_budget
        assert widths[-1] == cap
        assert all(w == cap for w in widths[-10:])

    def test_table_cap_limits_probing(self):
        """The controller never schedules a width the calibration curve
        marked above-tolerance (here: the MoE knee at width 1)."""
        cfg, t = _table("granite_moe_3b_a800m")
        g = _gran(cfg)
        clock = lambda w: decode_forward_cost(cfg, SLOTS, w, 256, g) \
            .time(TPU_V5E)
        c = BudgetController(table=t, mode="speculative", use_kernel=False)
        analytic = t.lookup("speculative", 256, False).analytic_nmax
        for _ in range(25):
            b = c.budget(200, SLOTS, analytic)
            w = max(1, b // SLOTS)
            # acceptance: the controlled loop never exceeds (1+eps)
            assert clock(w) / clock(1) <= 1 + EPS + 1e-9
            c.observe(200, w, clock(w))
        # ... while the static analytic budget demonstrably does
        w_static = max(1, analytic // SLOTS)
        assert clock(w_static) / clock(1) > 1 + EPS

    def test_aimd_recovers_when_live_knee_is_lower(self):
        """Stale-ish calibration: live latency violates AT the table
        cap; the controller shrinks below it and stays within tolerance
        thereafter (except the gated detection steps)."""
        _, t = _table("stablelm_3b")
        entry = t.lookup("speculative", 256, False)
        base = entry.baseline_time
        live_knee = 4                       # live boundary, << table cap
        clock = lambda w: base * (1.0 if w <= live_knee else 2.0)
        c = self._controller(table=t, patience=1, cooldown=10)
        widths = []
        for _ in range(60):
            b = c.budget(256, 1, entry.analytic_nmax)
            c.observe(256, b, clock(b))
            widths.append(b)
        # converged region never revisits the violating widths for long:
        # at most one probing step above the live knee per cooldown window
        tail = widths[-20:]
        assert sum(1 for w in tail if w > live_knee) <= 2
        assert c.stats()["shrinks"] >= 1

    def test_baseline_grace_falls_back_to_capped_static(self):
        """An adapter that never runs width-1 forwards (diffusion with a
        fixed block size) can never form a baseline: after the grace
        window the controller defers to the capped static budget
        instead of pinning the reported budget to n_active forever."""
        _, t = _table("stablelm_3b")              # calibrated knee 60
        grace = 4
        c = self._controller(table=t, baseline_grace=grace)
        c.bind("speculative", False, clocked=False)   # wall-clock loop,
        # simulator table -> baseline cannot seed (sources differ)
        assert c.budget(256, 4, 60) == 4          # warmup: width-1 ask
        for _ in range(grace):
            c.observe(256, 9, 1.0)                # adapter ignored it
        # fallback: capped static spend (min(60//4, 60) * 4), honest
        # telemetry instead of a forever-pinned n_active
        assert c.budget(256, 4, 60) == 60
        c_free = self._controller(baseline_grace=grace)   # no table
        for _ in range(grace):
            c_free.observe(256, 9, 1.0)
        assert c_free.budget(256, 4, 40) == 40    # analytic pass-through

    def test_stats_shape(self):
        c = self._controller()
        c.budget(100, 2, 16)
        c.observe(100, 1, 1.0)
        s = c.stats()
        assert set(s) == {"shrinks", "probes", "gated", "buckets"}
        (b,) = s["buckets"].values()
        assert {"width", "cap", "baseline_s", "noise"} <= set(b)


# ===========================================================================
# Scheduler integration (real engine; slow lane)
# ===========================================================================

@pytest.fixture(scope="module")
def tiny_setup():
    import jax
    from repro.models import init_model
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(i + 1), (5 + i,), 0, cfg.vocab_size))
        for i in range(3)]
    return cfg, params, prompts


@pytest.mark.slow
def test_wallclock_calibration_on_live_engine(tiny_setup):
    """The wallclock backend times real decode_slots forwards: the
    table comes back well-formed, engine state is restored, and the
    cache-headroom guards hold (width grid shrinks with max_len;
    oversized explicit grids refuse)."""
    import jax.numpy as jnp
    from repro.autotune import calibrate_engine
    from repro.serving import DecodeEngine
    cfg, params, _ = tiny_setup
    eng = DecodeEngine(cfg, params, batch=2, max_len=64)
    lens_before = np.asarray(eng.slot_lens).copy()
    t = calibrate_engine(eng, modes=("greedy",), backend="wallclock",
                         ns=(1, 2), warmup=0, rounds=1, iters=1)
    assert t.backend == "wallclock"
    for e in t.entries:
        assert e.ell + 2 <= eng.max_len          # headroom held
        assert all(x > 0 for x in e.times)
        assert e.noise >= 0.0
        assert 1 <= e.calibrated_budget <= e.analytic_nmax
    assert np.array_equal(np.asarray(eng.slot_lens), lens_before)
    assert eng.cache_len == jnp.zeros((), jnp.int32)
    # default grid scales down with max_len instead of overrunning it
    t2 = calibrate_engine(eng, modes=("greedy",), backend="simulator")
    assert all(e.ell + max(e.ns) <= eng.max_len for e in t2.entries)
    with pytest.raises(ValueError, match="overruns"):
        calibrate_engine(eng, modes=("greedy",), backend="wallclock",
                         ns=(1, 63), buckets=(64,))


@pytest.mark.slow
def test_golden_greedy_controller_byte_identical(tiny_setup):
    """A ServingLoop with the BudgetController enabled must stay
    byte-identical per request to the static-budget loop in greedy
    mode: the controller reshapes budgets, never tokens."""
    from repro.serving import DecodeEngine, ServingLoop
    cfg, params, prompts = tiny_setup
    outs = []
    for controller in (None, BudgetController()):
        eng = DecodeEngine(cfg, params, batch=2, max_len=128)
        loop = ServingLoop(eng, mode="greedy", controller=controller)
        for p in prompts:
            loop.submit(p, 10)
        outs.append(loop.run())
    static, controlled = outs
    assert sorted(static) == sorted(controlled)
    for rid in static:
        assert np.array_equal(static[rid], controlled[rid]), rid


@pytest.mark.slow
def test_serving_loop_controlled_vs_static_latency(tiny_setup):
    """End-to-end acceptance on a REAL ServingLoop: with the full-size
    MoE config's simulated clock injected, the static analytic budget
    exceeds the (1+eps) latency tolerance while the calibrated
    controller never does — and the step_log carries the full budget
    provenance."""
    import jax
    from repro.models import init_model
    from repro.serving import DecodeEngine, ServingLoop
    arch = "granite_moe_3b_a800m"
    cfg_full, table = _table(arch)
    g = _gran(cfg_full)

    def clock(width, ell):
        bucket = table.lookup(None, ell).ell
        return decode_forward_cost(cfg_full, SLOTS, width, bucket,
                                   g).time(TPU_V5E)

    red = get_config(arch, reduced=True)
    params = init_model(jax.random.PRNGKey(0), red)
    ratios = {}
    for name, controller in (("static", None),
                             ("controlled", BudgetController(table=table))):
        eng = DecodeEngine(red, params, batch=SLOTS, max_len=MAX_LEN_T)
        loop = ServingLoop(eng, mode="speculative", eps=EPS,
                           controller=controller, step_clock=clock)
        for i in range(4):
            prompt = np.asarray(jax.random.randint(
                jax.random.PRNGKey(50 + i), (6,), 0, red.vocab_size))
            loop.submit(prompt, 8)
        loop.run()
        ratios[name] = max(clock(e["width"], e["ell"])
                           / clock(1, e["ell"]) for e in loop.step_log)
        for e in loop.step_log:
            assert "budget_analytic" in e and "ell" in e
        if controller is not None:
            s = loop.stats()
            assert "controller" in s
            assert s.get("max_latency_ratio", 1.0) <= 1 + EPS + 1e-6
            assert any("budget_calibrated" in e for e in loop.step_log)
    assert ratios["static"] > 1 + EPS
    assert ratios["controlled"] <= 1 + EPS + 1e-9


MAX_LEN_T = 128


def test_budget_floor_regression_fractional_boundary():
    """Satellite regression: the deployment budget FLOORS a fractional
    boundary (rounding up would spend one position past the knee).
    At b=9 on TPU v5e the dense idle term is rho*s/(2b) ~= 26.73."""
    from repro.core import parallelism_budget, predict_model
    cfg = get_config("stablelm_3b")
    g = _gran(cfg)
    pred = predict_model(cfg, TPU_V5E, g, b=9, ell=256)
    assert pred.n_max != int(pred.n_max)          # genuinely fractional
    assert round(pred.n_max) > math.floor(pred.n_max)   # would round UP
    assert parallelism_budget(cfg, TPU_V5E, g, b=9, ell=256) \
        == math.floor(pred.n_max)
