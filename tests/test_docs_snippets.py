"""Smoke-run every documented command so the docs cannot rot.

Extracts all ```bash fenced blocks from README.md and docs/*.md, scales
the obviously-expensive knobs down to --tiny proportions (token/step/
request counts), and runs each ``PYTHONPATH=src python -m ...`` command
as a subprocess, asserting exit code 0.  Meta commands (pip install,
the pytest lanes themselves) are skipped, but their presence is still
asserted to follow the documented shape — any bash block this test
does not recognize FAILS, which forces new documentation to stay
runnable.

Slow-marked: the dedicated `docs` CI job (and the nightly full lane)
runs this file explicitly.
"""
from __future__ import annotations

import os
import pathlib
import re
import shlex
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

# documented values -> smoke-scale values (docs keep realistic numbers,
# CI runs tiny ones)
SCALE = {
    "--tokens": 6,
    "--steps": 2,
    "--requests": 3,
    "--slots": 2,
    "--prompt-len": 5,
}
SKIP_PATTERNS = (
    re.compile(r"^pip install"),          # environment setup
    re.compile(r"-m pytest\b"),           # the test lanes themselves
)


def _bash_blocks(text: str):
    return re.findall(r"```bash\n(.*?)```", text, re.S)


def _commands():
    cmds = []
    for path in DOC_FILES:
        for block in _bash_blocks(path.read_text()):
            for line in block.replace("\\\n", " ").splitlines():
                line = line.split("  #")[0].strip()
                if line:
                    cmds.append(pytest.param(
                        path.name, line,
                        id=f"{path.name}:{line[:70]}"))
    return cmds


def _scaled(cmd: str) -> str:
    for flag, val in SCALE.items():
        cmd = re.sub(rf"(?<=\s){re.escape(flag)} (\d+)",
                     lambda m, v=val: f"{flag} {min(int(m.group(1)), v)}",
                     cmd)
    return cmd


def test_docs_have_snippets():
    """The extraction itself must keep finding the documented commands
    (a regression here means the docs layout broke the smoke tests)."""
    cmds = [p.values[1] for p in _commands()]
    assert sum("repro.launch.serve" in c for c in cmds) >= 3
    assert any("repro.launch.train" in c for c in cmds)
    assert any("benchmarks." in c for c in cmds)


@pytest.mark.slow
@pytest.mark.parametrize(("source", "cmd"), _commands())
def test_doc_snippet_runs(source, cmd):
    if any(p.search(cmd) for p in SKIP_PATTERNS):
        pytest.skip("meta command (install / test lane), not smoke-run")
    assert cmd.startswith("PYTHONPATH=src python -m "), (
        f"{source}: bash snippets must be PYTHONPATH=src python -m "
        f"one-liners so this smoke test can run them; got: {cmd!r}")
    argv = shlex.split(_scaled(cmd))
    env = os.environ.copy()
    assignments = {}
    while argv and "=" in argv[0] and not argv[0].startswith("-"):
        k, v = argv.pop(0).split("=", 1)
        assignments[k] = v
    src = str(ROOT / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.update({k: (v if k != "PYTHONPATH" else env["PYTHONPATH"])
                for k, v in assignments.items()})
    env.setdefault("JAX_PLATFORMS", "cpu")
    assert argv[0] == "python"
    proc = subprocess.run([sys.executable, *argv[1:]], cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, (
        f"documented command failed ({source}): {cmd}\n"
        f"--- stdout ---\n{proc.stdout[-2000:]}\n"
        f"--- stderr ---\n{proc.stderr[-2000:]}")
