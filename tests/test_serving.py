"""Serving: engine semantics, speculative losslessness, diffusion decode,
NFP budget integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serving import (DecodeEngine, DiffusionBlockDecoder,
                           SpeculativeDecoder, ngram_draft)

KEY = jax.random.PRNGKey(0)

# multi-step generate loops over the reduced model — nightly lane
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    return cfg, params, prompt


def test_engine_multi_position_step(dense_setup):
    cfg, params, prompt = dense_setup
    eng = DecodeEngine(cfg, params, batch=1, max_len=128)
    eng.prefill(prompt)
    logits = eng.decode_step(jax.random.randint(KEY, (1, 4), 0,
                                                cfg.vocab_size))
    assert logits.shape == (1, 4, cfg.vocab_size)
    assert int(eng.cache_len) == prompt.shape[1] + 4


def test_speculative_matches_ar_greedy(dense_setup):
    """Greedy speculative decoding is LOSSLESS: identical to AR greedy."""
    cfg, params, prompt = dense_setup
    eng = DecodeEngine(cfg, params, batch=1, max_len=256)
    ar = np.asarray(eng.greedy_generate(prompt, 24)[0])
    for gamma in (2, 4, 7):
        eng2 = DecodeEngine(cfg, params, batch=1, max_len=256)
        toks, stats = SpeculativeDecoder(eng2, gamma=gamma).generate(
            prompt, 24)
        assert np.array_equal(ar, toks[:24]), gamma
        assert stats["tokens_per_forward"] >= 1.0


def test_speculative_uses_nfp_budget(dense_setup):
    cfg, params, prompt = dense_setup
    eng = DecodeEngine(cfg, params, batch=1, max_len=256)
    spec = SpeculativeDecoder(eng)          # gamma=None -> NFP budget
    budget = eng.nfp_budget()
    assert spec._gamma() == max(1, budget - 1)


def test_diffusion_block_decode(dense_setup):
    cfg, params, prompt = dense_setup
    eng = DecodeEngine(cfg, params, batch=1, max_len=256)
    dec = DiffusionBlockDecoder(eng, block_size=8, refine_steps=2)
    toks, stats = dec.generate(prompt, 16)
    assert len(toks) == 16
    assert stats["tokens_per_forward"] > 1.5   # block parallelism realized
    mask_id = cfg.vocab_size - 1
    assert not np.any(toks == mask_id)         # everything resolved


def test_ngram_draft_repeats_patterns():
    ctx = np.asarray([5, 6, 7, 5, 6], np.int64)
    out = ngram_draft(ctx, gamma=2, vocab_size=100)
    assert out[0] == 7                          # suffix [5,6] -> 7


def test_nfp_budget_tracks_batch(dense_setup):
    """The budget must shrink as serving batch grows (rho*s/2b term)."""
    cfg, params, _ = dense_setup
    budgets = []
    for b in (1, 4):
        eng = DecodeEngine(cfg, params, batch=b, max_len=64)
        eng.cache_len = jnp.asarray(32, jnp.int32)
        budgets.append(eng.nfp_budget())
    assert budgets[0] >= budgets[1]


def test_moe_engine_budget_routing_cases():
    # NOTE: skew <= bal holds when tau = E >= M_moe (paper's E=256 regime);
    # for tiny-E smoke configs the tau branch can bind the balanced case
    # first (Eq. 13 has tau, Eq. 14 does not) — so use the E=16 config.
    cfg = get_config("llada_mini_like", reduced=True)
    params = init_model(KEY, cfg)
    eng = DecodeEngine(cfg, params, batch=1, max_len=64)
    eng.cache_len = jnp.asarray(16, jnp.int32)
    bal = eng.nfp_budget(routing="balanced")
    skew = eng.nfp_budget(routing="skewed")
    assert skew <= bal                          # paper: skew = lower bound
    # full-size MoE (E=256, k=8): strict separation, paper Sec. 5.2
    from repro.core import GranularitySpec, TPU_V5E, predict_model
    full = get_config("llada_mini_like")
    g = GranularitySpec.for_backend(full.ffn.n_experts)
    b2 = predict_model(full, TPU_V5E, g, 1, 4096, routing="balanced")
    s2 = predict_model(full, TPU_V5E, g, 1, 4096, routing="skewed")
    assert s2.n_max < b2.n_max


def test_mtp_decoder_lossless_and_budgeted(dense_setup):
    """MTP verification forward = multi-position decode; greedy acceptance
    keeps the stream identical to AR greedy."""
    from repro.serving import MTPDecoder, init_mtp_heads
    cfg, params, prompt = dense_setup
    eng = DecodeEngine(cfg, params, batch=1, max_len=256)
    ar = np.asarray(eng.greedy_generate(prompt, 20)[0])
    heads = init_mtp_heads(jax.random.PRNGKey(5), cfg.d_model,
                           cfg.vocab_size, n_heads=4)
    eng2 = DecodeEngine(cfg, params, batch=1, max_len=256)
    dec = MTPDecoder(eng2, heads)
    assert dec._n() <= max(1, eng2.nfp_budget() - 1)   # budget respected
    toks, stats = dec.generate(prompt, 20)
    assert np.array_equal(ar, toks[:20])
    assert stats["tokens_per_forward"] >= 1.0


def test_mtp_loss_trains_heads():
    from repro.serving import init_mtp_heads, mtp_loss
    d, v = 32, 64
    heads = init_mtp_heads(jax.random.PRNGKey(0), d, v, 3,
                           dtype=jnp.float32)
    hidden = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, v)
    loss, grads = jax.value_and_grad(mtp_loss)(heads, hidden, tokens)
    assert np.isfinite(float(loss))
    g = np.asarray(grads["heads"], np.float32)
    assert np.abs(g).max() > 0
