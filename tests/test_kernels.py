"""Per-kernel validation: shape/dtype sweeps, Pallas interpret mode vs
pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.mamba_scan.ops import selective_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref
from repro.kernels.moe_ffn.ops import align_block_size, grouped_ffn
from repro.kernels.moe_ffn.ref import grouped_ffn_ref
from repro.models.layers import _init


KEY = jax.random.PRNGKey(0)


# ===========================================================================
# decode attention
# ===========================================================================

ATTN_CASES = [
    # (b, n, h, kv, dh, s_max, cache_len, window)
    (2, 1, 8, 2, 64, 256, 200, None),        # N=1 AR baseline, GQA
    (1, 7, 4, 4, 128, 300, 100, None),       # odd N, MHA
    (2, 17, 8, 1, 64, 512, 400, 128),        # MQA + sliding window
    (1, 64, 16, 8, 128, 1024, 900, None),    # exactly one q tile
    (1, 65, 16, 8, 128, 1024, 900, None),    # crosses the q-tile boundary
    (2, 3, 6, 3, 32, 128, 60, None),         # odd head dim count
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_vs_ref(case, dtype):
    b, n, h, kv, dh, s, cl, win = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, n, h, dh)).astype(dtype)
    filled = cl + n
    kc = jnp.zeros((b, s, kv, dh), dtype).at[:, :filled].set(
        jax.random.normal(ks[1], (b, filled, kv, dh)).astype(dtype))
    vc = jnp.zeros((b, s, kv, dh), dtype).at[:, :filled].set(
        jax.random.normal(ks[2], (b, filled, kv, dh)).astype(dtype))
    out = decode_attention(q, kc, vc, cl + n, window=win, interpret=True)
    ref = decode_attention_ref(q, kc, vc, cl, window=win)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_decode_attention_padded_rows_do_not_leak():
    """Rows beyond N are padding; output must only contain the N real rows
    and they must be unaffected by the pad (compare n=1 vs n=1-in-tile-64)."""
    b, h, kv, dh, s, cl = 1, 4, 2, 64, 256, 100
    ks = jax.random.split(KEY, 3)
    kc = jax.random.normal(ks[1], (b, s, kv, dh))
    vc = jax.random.normal(ks[2], (b, s, kv, dh))
    q1 = jax.random.normal(ks[0], (b, 1, h, dh))
    out1 = decode_attention(q1, kc, vc, cl + 1, interpret=True)
    assert out1.shape == (b, 1, h, dh)
    assert not bool(jnp.any(jnp.isnan(out1)))


# ===========================================================================
# MoE grouped FFN
# ===========================================================================

MOE_CASES = [
    (8, 64, 32, 4, "swiglu"),
    (33, 128, 256, 8, "swiglu"),
    (64, 64, 512, 4, "gelu"),
    (100, 256, 1024, 16, "swiglu"),
    (1, 32, 64, 8, "swiglu"),          # single token (decode N=1)
]


@pytest.mark.parametrize("case", MOE_CASES)
def test_grouped_ffn_vs_ref(case):
    m, d, f, e, act = case
    ks = jax.random.split(KEY, 4)
    params = {"w_up": _init(ks[0], (e, d, f), dtype=jnp.float32),
              "w_gate": _init(ks[1], (e, d, f), dtype=jnp.float32),
              "w_down": _init(ks[2], (e, f, d), dtype=jnp.float32)}
    gs = np.random.default_rng(m).multinomial(m, np.ones(e) / e)
    gs = jnp.asarray(gs, jnp.int32)
    x = jax.random.normal(ks[3], (m, d), jnp.float32)
    out = grouped_ffn(x, params, gs, act, interpret=True)
    ref = grouped_ffn_ref(x, params, gs, act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=5e-4)


def test_align_block_size_staircase():
    """The padded layout implements Eq. 28: per-expert ceil to token_block."""
    e, tb = 8, 16
    gs = jnp.asarray([1, 0, 17, 16, 3, 0, 0, 31], jnp.int32)
    m = int(gs.sum())
    expert_of = jnp.repeat(jnp.arange(e), gs, total_repeat_length=m)
    slot, block_expert, block_valid, m_pad_max = align_block_size(
        expert_of, gs, e, tb)
    # slots unique & within bounds
    assert len(set(np.asarray(slot).tolist())) == m
    assert int(slot.max()) < m_pad_max
    # executed blocks = sum ceil(counts/tb)
    expect_blocks = sum(int(np.ceil(c / tb)) for c in np.asarray(gs) if c)
    assert int(block_valid.sum()) == expect_blocks
    # vLLM bound: numel + E*(block-1), rounded up
    assert m_pad_max <= ((m + e * (tb - 1) + tb - 1) // tb) * tb


def test_grouped_ffn_skewed_routing():
    """All tokens on the same experts (paper's lower-bound case)."""
    m, d, f, e = 48, 64, 128, 16
    ks = jax.random.split(KEY, 4)
    params = {"w_up": _init(ks[0], (e, d, f), dtype=jnp.float32),
              "w_gate": _init(ks[1], (e, d, f), dtype=jnp.float32),
              "w_down": _init(ks[2], (e, f, d), dtype=jnp.float32)}
    gs = jnp.zeros((e,), jnp.int32).at[0].set(24).at[1].set(24)
    x = jax.random.normal(ks[3], (m, d), jnp.float32)
    out = grouped_ffn(x, params, gs, "swiglu", interpret=True)
    ref = grouped_ffn_ref(x, params, gs, "swiglu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=5e-4)


# ===========================================================================
# mamba selective scan
# ===========================================================================

SCAN_CASES = [(2, 16, 64, 16), (1, 7, 32, 8), (2, 33, 128, 16), (1, 1, 64, 16)]


@pytest.mark.parametrize("case", SCAN_CASES)
def test_selective_scan_vs_ref(case):
    b, s, di, ds = case
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    b_in = jax.random.normal(ks[2], (b, s, ds))
    c_in = jax.random.normal(ks[3], (b, s, ds))
    a = -jnp.exp(jax.random.normal(ks[4], (di, ds)) * 0.5)
    h0 = jax.random.normal(ks[5], (b, di, ds))
    y, h = selective_scan(x, dt, b_in, c_in, a, h0, interpret=True)
    yr, hr = selective_scan_ref(x, dt, b_in, c_in, a, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4)


def test_scan_chunk_padding_is_identity():
    """Padded steps (dt=0) must not change the final state."""
    b, s, di, ds = 1, 5, 16, 8       # 5 pads to 16
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    b_in = jax.random.normal(ks[2], (b, s, ds))
    c_in = jax.random.normal(ks[3], (b, s, ds))
    a = -jnp.exp(jax.random.normal(ks[4], (di, ds)) * 0.5)
    h0 = jnp.zeros((b, di, ds))
    _, h = selective_scan(x, dt, b_in, c_in, a, h0, interpret=True)
    _, hr = selective_scan_ref(x, dt, b_in, c_in, a, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5)
