"""Budget-aware multi-request scheduler: losslessness vs solo greedy
decoding, NFP position-budget enforcement, continuous batching, and the
unified ParallelDecodeAlgorithm protocol (incl. the draft-cache resync
fix in the speculative driver)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serving import (DecodeEngine, DiffusionBlockDecoder, MTPDecoder,
                           ParallelDecodeAlgorithm, ServingLoop,
                           SpeculativeDecoder, init_mtp_heads)

KEY = jax.random.PRNGKey(0)
TOKENS = 16

# full serving loops (solo references + batched runs) — nightly lane;
# the tier-1 lane keeps the kernel-path golden tests in test_ragged_decode
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(i + 1), (6 + i,), 0, cfg.vocab_size))
        for i in range(5)]
    refs = []
    for p in prompts:
        eng = DecodeEngine(cfg, params, batch=1, max_len=256)
        refs.append(np.asarray(
            eng.greedy_generate(jnp.asarray(p)[None], TOKENS)[0]))
    return cfg, params, prompts, refs


def _run_loop(cfg, params, prompts, mode, slots=4, max_width=8):
    eng = DecodeEngine(cfg, params, batch=slots, max_len=256)
    loop = ServingLoop(eng, mode=mode, max_width=max_width)
    for p in prompts:
        loop.submit(p, TOKENS)
    return loop, loop.run()


def test_greedy_serving_matches_solo_greedy(setup):
    """>= 4 concurrent requests through ONE engine: every stream is
    byte-identical to running the request alone."""
    cfg, params, prompts, refs = setup
    loop, out = _run_loop(cfg, params, prompts[:4], "greedy")
    assert max(e["active"] for e in loop.step_log) == 4
    for i in range(4):
        assert np.array_equal(refs[i], out[i]), i


def test_speculative_serving_lossless(setup):
    """Budget-split n-gram verification windows stay lossless."""
    cfg, params, prompts, refs = setup
    loop, out = _run_loop(cfg, params, prompts[:4], "speculative")
    for i in range(4):
        assert np.array_equal(refs[i], out[i]), i
    # parallelism realized: some forwards carried > active positions
    assert loop.stats()["max_positions_per_forward"] > 4


def test_positions_per_forward_within_budget(setup):
    """Total positions per forward never exceed the NFP budget (with a
    floor of one position per active request)."""
    cfg, params, prompts, refs = setup
    for mode in ("greedy", "speculative"):
        loop, _ = _run_loop(cfg, params, prompts[:4], mode)
        assert loop.step_log
        for e in loop.step_log:
            assert e["positions"] <= max(e["budget"], e["active"]), (mode, e)
        assert loop.stats()["max_positions_per_forward"] > 0


def test_continuous_batching_queues_beyond_slots(setup):
    """More requests than slots: the queue drains through freed slots
    and every stream still matches its solo reference."""
    cfg, params, prompts, refs = setup
    loop, out = _run_loop(cfg, params, prompts, "greedy", slots=2)
    assert len(out) == len(prompts)
    assert max(e["active"] for e in loop.step_log) <= 2
    for i in range(len(prompts)):
        assert np.array_equal(refs[i], out[i]), i


def test_slot_isolation_prefill_does_not_clobber(setup):
    """Admitting a new request must not disturb resident slots' caches:
    interleaved admission (slots=2, staggered lengths) already exercises
    this, but check the cache lengths directly too."""
    cfg, params, prompts, _ = setup
    eng = DecodeEngine(cfg, params, batch=3, max_len=256)
    loop = ServingLoop(eng, mode="greedy")
    loop.submit(prompts[0], TOKENS)
    loop.submit(prompts[1], TOKENS)
    loop.admit()
    loop.step()
    lens_before = np.asarray(eng.slot_lens).copy()
    loop.submit(prompts[2], TOKENS)
    loop.admit()
    loop.step()
    lens_after = np.asarray(eng.slot_lens)
    # resident slots advanced by exactly their commit, newcomer prefilled
    assert lens_after[0] == lens_before[0] + 1
    assert lens_after[1] == lens_before[1] + 1
    assert lens_after[2] == len(prompts[2]) + 1


def test_all_drivers_implement_protocol(setup):
    cfg, params, _, _ = setup
    eng = DecodeEngine(cfg, params, batch=1, max_len=256)
    heads = init_mtp_heads(jax.random.PRNGKey(5), cfg.d_model,
                           cfg.vocab_size, n_heads=4)
    drivers = [SpeculativeDecoder(eng), DiffusionBlockDecoder(eng),
               MTPDecoder(eng, heads)]
    for d in drivers:
        assert isinstance(d, ParallelDecodeAlgorithm)
        assert d.parallel_width() >= 1


def test_draft_engine_cache_stays_synced(setup):
    """The draft-cache desync fix: with the draft sharing the target's
    weights, a coherent draft cache makes every draft token the AR
    continuation — full acceptance, gamma+1 tokens per forward."""
    cfg, params, prompts, refs = setup
    prompt = jnp.asarray(prompts[0])[None]
    gamma = 4
    eng = DecodeEngine(cfg, params, batch=1, max_len=256)
    draft = DecodeEngine(cfg, params, batch=1, max_len=256)
    dec = SpeculativeDecoder(eng, draft_engine=draft, gamma=gamma)
    toks, stats = dec.generate(prompt, TOKENS)
    assert np.array_equal(refs[0], toks[:TOKENS])     # lossless
    # full acceptance every round (a desynced draft cache collapses this
    # to ~1-2 tokens/forward); the last round may get a smaller gamma
    assert stats["tokens_per_forward"] >= gamma
