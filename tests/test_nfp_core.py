"""NFP core: paper-value reproduction + property tests (hypothesis)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (GranularitySpec, H20, H800, A800, TPU_V5E,
                        ai_attn, ai_dense, ai_moe, attn_padded_q,
                        balanced_moe_baseline_n, extract_nmax,
                        moe_padded_tokens, n_idle_attn, n_idle_dense,
                        n_idle_moe, predict_dense, predict_model,
                        predict_moe_balanced, predict_moe_skewed,
                        select_q_block, select_token_block)
from repro.core.measure import LatencyCurve


G256 = GranularitySpec.for_backend(n_experts=256)


# ===========================================================================
# Paper Table 24 (deployment lookup) — exact reproduction
# ===========================================================================

class TestPaperTable24:
    def test_dense_h20_b1(self):
        p = predict_dense(H20, G256, b=1)
        assert round(p.n_max) == 37 and round(p.n_idle) == 37

    def test_dense_h20_b4(self):
        p = predict_dense(H20, G256, b=4)
        assert round(p.n_max) == 9

    def test_dense_a800_attn_limited(self):
        p = predict_dense(A800, G256, b=1)
        assert p.n_max == 64 and p.limiting == "attn_tile"
        assert round(p.n_idle) == 153          # 2.4x over-prediction
        assert 2.3 < p.overprediction < 2.5

    def test_dense_h800_attn_limited(self):
        p = predict_dense(H800, G256, b=1)
        assert p.n_max == 64
        assert round(p.n_idle) == 295          # 4.6x over

    def test_moe_balanced_23x(self):
        p = predict_moe_balanced(H20, G256, n_experts=256, k=8, d_ff=512)
        assert p.n_max == 64
        assert 22 < p.overprediction < 24      # the paper's 23x headline

    def test_moe_balanced_k32(self):
        p = predict_moe_balanced(H20, G256, n_experts=256, k=32, d_ff=512)
        assert p.n_max == 64
        assert 5.3 < p.overprediction < 6.0    # ~5.7x

    def test_moe_skewed(self):
        p = predict_moe_skewed(H20, G256, k=8, d_ff=512)
        assert p.n_max == 16                   # M_moe
        assert 2.5 < p.overprediction < 3.1    # ~2.8x

    def test_moe_skewed_k_invariance(self):
        """Paper: skewed prediction ~45 nearly constant across k."""
        vals = [n_idle_moe(H20.rho, 1, k, e_act=k, d_ff=512)
                for k in (2, 8, 32, 128)]
        assert max(vals) / min(vals) < 1.6


# ===========================================================================
# Equation sanity (Eqs. 8-11)
# ===========================================================================

class TestEquations:
    def test_dense_ai_form(self):
        # AI = 2bN/s independent of dims
        assert ai_dense(10, 4, 2) == 40.0

    def test_dense_idle_balance_point(self):
        # AI(N_idle) == rho by construction
        n = n_idle_dense(TPU_V5E.rho, b=2)
        assert math.isclose(ai_dense(n, 2), TPU_V5E.rho, rel_tol=1e-9)

    def test_attn_idle_memory_bound_regime(self):
        # 2L <= rho*s -> infinite boundary
        assert n_idle_attn(H20.rho, ell=30) == float("inf")
        assert n_idle_attn(H20.rho, ell=4096) > 0

    def test_attn_idle_balance(self):
        ell = 8192
        n = n_idle_attn(H20.rho, ell)
        assert math.isclose(ai_attn(n, ell), H20.rho, rel_tol=1e-6)

    def test_moe_idle_balance(self):
        n = n_idle_moe(H20.rho, b=1, k=8, e_act=256, d_ff=512)
        assert math.isclose(ai_moe(n, 1, 8, 256, 512), H20.rho, rel_tol=1e-6)

    def test_balanced_baseline_eq26(self):
        assert balanced_moe_baseline_n(256, 1, 8) == 32
        assert balanced_moe_baseline_n(256, 1, 256) == 1


# ===========================================================================
# Property tests
# ===========================================================================

class TestProperties:
    @given(b1=st.integers(1, 64), b2=st.integers(1, 64))
    @settings(max_examples=50, deadline=None)
    def test_dense_boundary_monotone_in_batch(self, b1, b2):
        if b1 < b2:
            assert n_idle_dense(H20.rho, b1) >= n_idle_dense(H20.rho, b2)

    @given(n=st.integers(1, 4096))
    @settings(max_examples=100, deadline=None)
    def test_attn_padding_invariants(self, n):
        blk = select_q_block(n)
        pad = attn_padded_q(n)
        assert pad >= n and pad % blk == 0 and pad - n < blk

    @given(counts=st.lists(st.integers(0, 200), min_size=1, max_size=64),
           tb=st.sampled_from([16, 64, 128]))
    @settings(max_examples=100, deadline=None)
    def test_moe_padding_invariants(self, counts, tb):
        padded = moe_padded_tokens(counts, tb)
        logical = sum(counts)
        assert padded >= logical
        assert padded % tb == 0 or padded == 0
        # slack bounded by (tb-1) per active expert
        active = sum(1 for c in counts if c > 0)
        assert padded - logical <= active * (tb - 1) + active

    @given(m=st.integers(1, 2048), e=st.sampled_from([8, 40, 64, 256]))
    @settings(max_examples=100, deadline=None)
    def test_token_block_branches(self, m, e):
        tb = select_token_block(m, e)
        # mirrors Tables 8/9: small branch below tau=E, large above
        assert tb == (16 if m <= e else 64)

    @given(times=st.lists(st.floats(0.5, 2.0), min_size=3, max_size=20),
           eps=st.floats(0.05, 0.3))
    @settings(max_examples=100, deadline=None)
    def test_extract_nmax_is_sound(self, times, eps):
        ns = list(range(1, len(times) + 1))
        curve = LatencyCurve(ns, times, baseline_n=1)
        nmax = extract_nmax(curve, eps)
        assert nmax in ns
        t0 = times[0]
        # the returned boundary itself satisfies the tolerance
        assert times[ns.index(nmax)] <= (1 + eps) * t0 + 1e-12

    @given(eps1=st.floats(0.05, 0.15), eps2=st.floats(0.16, 0.3))
    @settings(max_examples=50, deadline=None)
    def test_nmax_monotone_in_tolerance(self, eps1, eps2):
        times = [1.0, 1.05, 1.1, 1.2, 1.25, 1.4, 2.0]
        curve = LatencyCurve(list(range(1, 8)), times)
        assert extract_nmax(curve, eps1) <= extract_nmax(curve, eps2)

    @given(b=st.integers(1, 32), ell=st.integers(64, 65536))
    @settings(max_examples=50, deadline=None)
    def test_model_prediction_is_min_of_terms(self, b, ell):
        from repro.configs import get_config
        cfg = get_config("phi3_medium_14b")
        p = predict_model(cfg, TPU_V5E, G256, b, ell)
        assert p.n_max == min(p.terms.values())
        # NOTE: n_max may exceed n_idle — granularity slack extends past
        # the idle-compute balance point (the paper's MoE/Attn finding).


# ===========================================================================
# Model-level composition across the 10 assigned archs
# ===========================================================================

class TestArchComposition:
    def test_attention_free_has_no_attn_term(self):
        from repro.configs import get_config
        cfg = get_config("falcon_mamba_7b")
        p = predict_model(cfg, TPU_V5E, GranularitySpec.for_backend(), 1, 4096)
        assert "attn_tile" not in p.terms          # inapplicable (DESIGN §6)
        assert "ssm_chunk_capacity" in p.terms

    def test_moe_arch_routing_bounds(self):
        from repro.configs import get_config
        cfg = get_config("granite_moe_3b_a800m")
        g = GranularitySpec.for_backend(cfg.ffn.n_experts)
        bal = predict_model(cfg, TPU_V5E, g, 1, 4096, routing="balanced")
        skew = predict_model(cfg, TPU_V5E, g, 1, 4096, routing="skewed")
        assert skew.n_max <= bal.n_max             # skew is the lower bound

    def test_all_archs_produce_finite_budget(self):
        from repro.configs import ARCH_IDS, get_config
        from repro.core import parallelism_budget
        for a in ARCH_IDS:
            cfg = get_config(a)
            g = GranularitySpec.for_backend(cfg.ffn.n_experts)
            n = parallelism_budget(cfg, TPU_V5E, g, b=1, ell=4096)
            assert n >= 1


class TestQuantBranchRules:
    """Paper Table 9: SGLang block-size branches depend on quantization."""

    def test_bf16_branches(self):
        assert select_token_block(8, 256, "bf16") == 16
        assert select_token_block(300, 256, "bf16") == 64

    def test_fp8_branches(self):
        assert select_token_block(8, 256, "fp8") == 64
        assert select_token_block(300, 256, "fp8") == 128

    def test_blockwise_fp8_any_m(self):
        assert select_token_block(1, 256, "fp8_block") == 64
        assert select_token_block(10000, 256, "fp8_block") == 64

    def test_quant_shifts_moe_boundary(self):
        """fp8's larger M_moe enlarges the skewed near-free region 4x
        (paper Sec. J.2.4: padding is a co-design knob)."""
        g16 = GranularitySpec.for_backend(n_experts=256, quant="bf16")
        g64 = GranularitySpec.for_backend(n_experts=256, quant="fp8")
        s16 = predict_moe_skewed(H20, g16, k=8, d_ff=512)
        s64 = predict_moe_skewed(H20, g64, k=8, d_ff=512)
        assert s64.n_max == 4 * s16.n_max
