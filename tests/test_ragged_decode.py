"""Ragged per-slot decode attention: lossless-verification harness.

Three layers of proof that the scheduler's kernel fast path is lossless:
  1. kernel parity — the ragged Pallas kernel (interpret mode on CPU)
     vs the pure-jnp oracle across GQA group sizes, SWA windows,
     q_block/k_block choices, and adversarial row-length mixes,
  2. layer parity — ``gqa_decode(use_kernel=True)`` vs the XLA reference
     with per-row cache lengths (rope + ragged cache writes included),
  3. golden equivalence — ``ServingLoop`` over the kernel path emits
     byte-identical token streams to solo ``DecodeEngine.greedy_generate``
     in greedy and speculative modes, with slack telemetry present and
     no fallback warning.

Plus the ``gqa_decode_ring`` SWA ring buffer (wraparound commits and
window masks across the seam) and ``slack_report`` invariants.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.arch import AttentionSpec
from repro.core.granularity import round_up, select_q_block
from repro.kernels.decode_attention.ops import (decode_attention,
                                                decode_attention_ragged,
                                                slack_report)
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.models.attention import gqa_decode, gqa_decode_ring, init_attention

KEY = jax.random.PRNGKey(0)


def _rand_qkv(b, n, h, kv, dh, s, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, n, h, dh)).astype(dtype)
    kc = jax.random.normal(ks[1], (b, s, kv, dh)).astype(dtype)
    vc = jax.random.normal(ks[2], (b, s, kv, dh)).astype(dtype)
    return q, kc, vc


# ===========================================================================
# 1. kernel parity vs oracle
# ===========================================================================

RAGGED_CASES = [
    # (b, n, h, kv, dh, s_max, lens, window, q_block, k_block)
    (4, 1, 8, 2, 64, 256, [0, 37, 200, 100], None, None, 128),    # N=1 mixed
    (4, 5, 8, 2, 64, 256, [0, 37, 200, 100], None, None, 128),    # len-0 row
    (3, 7, 4, 4, 32, 384, [60, 60, 60], None, None, 128),         # all-equal
    (2, 4, 8, 1, 64, 256, [252, 10], None, None, 128),            # max_len row
    (4, 3, 6, 3, 32, 256, [5, 100, 200, 253], None, 16, 64),      # qb16/kb64
    (4, 17, 8, 2, 64, 512, [0, 130, 255, 300], 128, None, 128),   # SWA mixed
    (2, 2, 4, 2, 32, 256, [128, 64], 32, 16, 128),                # tiny window
    (2, 65, 16, 8, 64, 256, [100, 5], None, None, 128),           # 2 q tiles
]


@pytest.mark.parametrize("case", RAGGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_kernel_vs_ref(case, dtype):
    b, n, h, kv, dh, s, lens, win, qb, kb = case
    q, kc, vc = _rand_qkv(b, n, h, kv, dh, s, dtype)
    lens = jnp.asarray(lens, jnp.int32)
    out = decode_attention_ragged(q, kc, vc, lens, window=win,
                                  q_block_override=qb, k_block=kb,
                                  interpret=True)
    ref = decode_attention_ref(q, kc, vc, lens, window=win)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_ragged_equals_rowwise_scalar_kernel():
    """The ragged launch must agree with running each row alone through the
    aligned (scalar total_len) kernel — raggedness cannot couple rows."""
    b, n, h, kv, dh, s = 4, 5, 8, 2, 64, 256
    lens = [0, 37, 200, 100]
    q, kc, vc = _rand_qkv(b, n, h, kv, dh, s)
    out = decode_attention_ragged(q, kc, vc, jnp.asarray(lens, jnp.int32),
                                  interpret=True)
    for bi, ln in enumerate(lens):
        solo = decode_attention(q[bi:bi + 1], kc[bi:bi + 1], vc[bi:bi + 1],
                                ln + n, interpret=True)
        np.testing.assert_allclose(np.asarray(out[bi:bi + 1]),
                                   np.asarray(solo), atol=2e-6, rtol=2e-6)


def test_scalar_broadcast_matches_aligned_entry():
    """decode_attention(total_len) is the ragged kernel with aligned rows."""
    b, n, h, kv, dh, s, cl = 2, 3, 4, 2, 32, 128, 60
    q, kc, vc = _rand_qkv(b, n, h, kv, dh, s)
    aligned = decode_attention(q, kc, vc, cl + n, interpret=True)
    ragged = decode_attention_ragged(
        q, kc, vc, jnp.full((b,), cl, jnp.int32), interpret=True)
    np.testing.assert_allclose(np.asarray(aligned), np.asarray(ragged),
                               atol=0, rtol=0)


# ===========================================================================
# 2. layer parity: gqa_decode kernel vs XLA reference, per-row lengths
# ===========================================================================

@pytest.mark.parametrize("kind,window", [("gqa", None), ("swa", 48)])
def test_gqa_decode_kernel_path_per_row(kind, window):
    a = AttentionSpec(kind=kind, n_heads=4, n_kv_heads=2, head_dim=32,
                      window=window)
    d = 64
    params = init_attention(jax.random.PRNGKey(1), d, a, dtype=jnp.float32)
    b, n, s = 3, 4, 128
    lens = jnp.asarray([0, 17, 90], jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(2), (b, n, d), jnp.float32)
    cache = {"k": jax.random.normal(jax.random.PRNGKey(3), (b, s, 2, 32)),
             "v": jax.random.normal(jax.random.PRNGKey(4), (b, s, 2, 32))}
    out_k, cache_k = gqa_decode(params, a, x, cache, lens, 10000.0,
                                use_kernel=True)
    out_r, cache_r = gqa_decode(params, a, x, cache, lens, 10000.0,
                                use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-4, rtol=1e-4)
    # the cache write path is shared — must be identical
    np.testing.assert_array_equal(np.asarray(cache_k["k"]),
                                  np.asarray(cache_r["k"]))


# ===========================================================================
# 3. golden equivalence: ServingLoop kernel path vs solo greedy decode
# ===========================================================================

@pytest.fixture(scope="module")
def serving_setup():
    from repro.configs import get_config
    from repro.models import init_model
    from repro.serving import DecodeEngine
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(i + 1), (6 + i,), 0, cfg.vocab_size))
        for i in range(3)]
    refs = []
    for p in prompts:
        eng = DecodeEngine(cfg, params, batch=1, max_len=256)
        refs.append(np.asarray(
            eng.greedy_generate(jnp.asarray(p)[None], 12)[0]))
    return cfg, params, prompts, refs


@pytest.mark.parametrize("mode", ["greedy", "speculative"])
def test_serving_kernel_path_golden(serving_setup, mode):
    """ServingLoop(use_kernel=True): byte-identical to solo greedy decode,
    no fallback warning, slack telemetry in every step entry."""
    from repro.serving import DecodeEngine, ServingLoop
    cfg, params, prompts, refs = serving_setup
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng = DecodeEngine(cfg, params, batch=3, max_len=256,
                           use_kernel=True)
        loop = ServingLoop(eng, mode=mode, max_width=6)
        for p in prompts:
            loop.submit(p, 12)
        out = loop.run()
    for i in range(len(prompts)):
        assert np.array_equal(refs[i], out[i]), i
    for e in loop.step_log:
        for k in ("attn_row_util", "kv_tiles_executed", "kv_tiles_grid",
                  "kv_tiles_skipped", "kv_tile_util"):
            assert k in e
        assert 0 < e["kv_tiles_executed"] <= e["kv_tiles_grid"]
    assert "mean_kv_tile_util" in loop.stats()


def test_solo_kernel_engine_matches_reference_engine(serving_setup):
    """Single-request greedy decode through the kernel path is also
    byte-identical to the XLA reference engine."""
    from repro.serving import DecodeEngine
    cfg, params, prompts, refs = serving_setup
    eng = DecodeEngine(cfg, params, batch=1, max_len=256, use_kernel=True)
    toks = np.asarray(
        eng.greedy_generate(jnp.asarray(prompts[0])[None], 12)[0])
    assert np.array_equal(refs[0], toks)


# ===========================================================================
# gqa_decode_ring: SWA ring buffer across the wraparound seam
# ===========================================================================

def test_ring_decode_matches_full_cache_across_seam():
    """Drive ring (O(window) buffer) and full-cache SWA decode in lockstep
    past the wraparound: outputs must agree at every step, including the
    steps whose window spans the ring seam."""
    a = AttentionSpec(kind="swa", n_heads=4, n_kv_heads=2, head_dim=32,
                      window=32)
    d, b, n, w_buf, s_full = 64, 2, 4, 48, 192
    params = init_attention(jax.random.PRNGKey(5), d, a, dtype=jnp.float32)
    ring = {"k": jnp.zeros((b, w_buf, 2, 32)), "v": jnp.zeros((b, w_buf, 2, 32))}
    full = {"k": jnp.zeros((b, s_full, 2, 32)), "v": jnp.zeros((b, s_full, 2, 32))}
    steps = (s_full - n) // n                     # 47 commits -> 3+ wraps
    wrapped = False
    for step in range(steps):
        cl = step * n
        x = jax.random.normal(jax.random.fold_in(KEY, step), (b, n, d),
                              jnp.float32)
        out_r, ring = gqa_decode_ring(params, a, x, ring, cl, 10000.0)
        out_f, full = gqa_decode(params, a, x, full, cl, 10000.0)
        np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_f),
                                   atol=1e-4, rtol=1e-4, err_msg=f"step {step}")
        wrapped |= cl + n > w_buf
    assert wrapped                                # the seam was crossed


def test_ring_wraparound_slot_contents():
    """After wrapping, each ring slot must hold the LARGEST position
    congruent to it — verified by committing recognizable values."""
    a = AttentionSpec(kind="swa", n_heads=2, n_kv_heads=1, head_dim=8,
                      window=8)
    d, b, n, w_buf = 16, 1, 2, 16
    params = init_attention(jax.random.PRNGKey(6), d, a, dtype=jnp.float32)
    ring = {"k": jnp.zeros((b, w_buf, 1, 8)), "v": jnp.zeros((b, w_buf, 1, 8))}
    total = 3 * w_buf + n                        # several full wraps
    for cl in range(0, total, n):
        x = jnp.full((b, n, d), 0.0).at[:, :, 0].set(
            cl + jnp.arange(n, dtype=jnp.float32))   # position tag
        _, ring = gqa_decode_ring(params, a, x, ring, cl, 10000.0)
    # position p lives in slot p % w_buf; last writes win
    k = np.asarray(ring["k"])                    # (b, w_buf, 1, 8)
    assert k.shape[1] == w_buf
    # every slot was overwritten at least twice (no stale zeros)
    assert np.all(np.abs(k).sum(axis=(2, 3)) > 0)


# ===========================================================================
# slack_report invariants
# ===========================================================================

def test_slack_report_bounds_and_monotonicity():
    lens = np.asarray([0, 37, 200, 100])
    rep = slack_report(5, lens, 256, head_dim=64)
    assert rep["kv_tiles_useful"] <= rep["kv_tiles_executed"] <= rep["kv_tiles_grid"]
    assert rep["kv_tiles_skipped"] == rep["kv_tiles_grid"] - rep["kv_tiles_executed"]
    assert 0 < rep["row_utilization"] <= 1
    # longer slots -> at least as many executed tiles
    rep2 = slack_report(5, lens + 40, 256, head_dim=64)
    assert rep2["kv_tiles_executed"] >= rep["kv_tiles_executed"]
    # inactive rows move tiles from useful to pure slack
    rep3 = slack_report(5, lens, 256, head_dim=64,
                        active=[True, True, False, False])
    assert rep3["kv_tiles_useful"] < rep3["kv_tiles_executed"]
    assert rep3["kv_tiles_executed"] == rep["kv_tiles_executed"]


def test_slack_report_matches_kernel_tiling():
    """The report's q_block/physical-rows model must equal the launch math
    in ops.decode_attention_ragged."""
    for n in (1, 5, 64, 65):
        rep = slack_report(n, np.zeros(2, np.int64), 256, head_dim=64)
        qb = select_q_block(n, 64)
        assert rep["q_block"] == qb
        assert rep["rows_physical"] == 2 * round_up(n, qb)
