"""Per-arch smoke tests (reduced configs) + decode-cache consistency.

Each assigned architecture: instantiate the reduced config, run one
forward/train step on CPU, assert output shapes + no NaNs (brief
requirement), plus prefill+decode == full-forward consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config

# full-model integration sweep over every arch — the nightly lane's job
pytestmark = pytest.mark.slow
from repro.models import forward, init_cache, init_model
from repro.training import AdamWConfig, init_opt_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, b, s, key=KEY):
    if cfg.family == "vlm":
        return {"embeds": jax.random.normal(key, (b, s, cfg.d_model),
                                            jnp.bfloat16)}
    inp = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.encoder is not None:
        inp["frames"] = jax.random.normal(
            key, (b, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
    return inp


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_config(arch, reduced=True)
    params = init_model(KEY, cfg)
    b, s = 2, 16
    logits, _, aux, _ = forward(params, cfg, _inputs(cfg, b, s), mode="train")
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = init_model(KEY, cfg)
    opt = init_opt_state(params)
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10))
    b, s = 2, 16
    batch = _inputs(cfg, b, s)
    if "tokens" not in batch:   # vlm: labels still needed
        batch["tokens"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    # parameters actually moved
    moved = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b_, np.float32))
        for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode(arch):
    cfg = get_config(arch, reduced=True)
    params = init_model(KEY, cfg)
    b, s, n = 2, 12, 4
    cache = init_cache(cfg, b, s + n)
    inp = _inputs(cfg, b, s)
    _, cache, _, _ = forward(params, cfg, inp, mode="prefill", cache=cache,
                          cache_len=0)
    dec_in = _inputs(cfg, b, n, key=jax.random.PRNGKey(7))
    if cfg.encoder is not None:
        dec_in["frames"] = inp["frames"]
    logits, cache2, _, _ = forward(params, cfg, dec_in, mode="decode",
                                cache=cache,
                                cache_len=jnp.asarray(s, jnp.int32))
    assert logits.shape == (b, n, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


CONSISTENCY_ARCHS = ["stablelm_3b", "starcoder2_3b", "mixtral_8x22b",
                     "falcon_mamba_7b", "zamba2_1p2b", "granite_moe_3b_a800m"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    """The multi-position decode forward over a cache must agree with the
    full forward — exact in bf16 for everything but reordered matmuls."""
    cfg = get_config(arch, reduced=True)
    params = init_model(jax.random.PRNGKey(1), cfg)
    b, s, n = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + n), 0,
                              cfg.vocab_size)
    full, _, _, _ = forward(params, cfg, {"tokens": toks}, mode="train")
    cache = init_cache(cfg, b, s + n)
    _, cache, _, _ = forward(params, cfg, {"tokens": toks[:, :s]},
                          mode="prefill", cache=cache, cache_len=0)
    dec, _, _, _ = forward(params, cfg, {"tokens": toks[:, s:]}, mode="decode",
                        cache=cache, cache_len=jnp.asarray(s, jnp.int32))
    a = np.asarray(full[:, s:], np.float32)
    c = np.asarray(dec, np.float32)
    err = np.max(np.abs(a - c)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 1e-5, err


def test_mla_consistency_f32():
    """MLA absorbed decode vs non-absorbed prefill agree in f32."""
    cfg = get_config("minicpm3_4b", reduced=True)
    params = init_model(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    b, s, n = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + n), 0,
                              cfg.vocab_size)
    full, _, _, _ = forward(params, cfg, {"tokens": toks}, mode="train")
    cache = init_cache(cfg, b, s + n, dtype=jnp.float32)
    _, cache, _, _ = forward(params, cfg, {"tokens": toks[:, :s]},
                          mode="prefill", cache=cache, cache_len=0)
    dec, _, _, _ = forward(params, cfg, {"tokens": toks[:, s:]}, mode="decode",
                        cache=cache, cache_len=jnp.asarray(s, jnp.int32))
    a = np.asarray(full[:, s:], np.float32)
    c = np.asarray(dec, np.float32)
    err = np.max(np.abs(a - c)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 1e-4, err


def test_swa_window_masks_old_tokens():
    """Sliding-window attention must ignore tokens beyond the window."""
    cfg = get_config("mixtral_8x22b", reduced=True)     # window=8
    params = init_model(KEY, cfg)
    b, s = 1, 20
    t1 = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    # perturb a token far outside the window of the last position
    t2 = t1.at[0, 2].set((t1[0, 2] + 1) % cfg.vocab_size)
    l1, _, _, _ = forward(params, cfg, {"tokens": t1}, mode="train")
    l2, _, _, _ = forward(params, cfg, {"tokens": t2}, mode="train")
    # windowed attention -> last position unaffected... through attention;
    # (the MoE router is also token-local, so only position 2 changes)
    np.testing.assert_allclose(np.asarray(l1[0, -1], np.float32),
                               np.asarray(l2[0, -1], np.float32),
                               atol=1e-5)


def test_param_count_close_to_billing():
    """Full configs should land near their advertised sizes."""
    import math
    expect = {"phi3_medium_14b": 14e9, "starcoder2_3b": 3e9,
              "falcon_mamba_7b": 7.3e9, "mixtral_8x22b": 141e9,
              "stablelm_3b": 2.8e9}
    for arch, target in expect.items():
        n = get_config(arch).param_count()
        assert 0.5 * target < n < 1.6 * target, (arch, n, target)


def test_swa_ring_buffer_matches_full_cache():
    """O(window) ring cache must be bit-equivalent to the O(seq) cache
    through multiple wraparounds (multi-position blocks included)."""
    cfg = get_config("mixtral_8x22b", reduced=True)     # window=8
    params = init_model(jax.random.PRNGKey(0), cfg)
    b, total = 2, 40
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, total), 0,
                              cfg.vocab_size)
    blocks = [1, 3, 2, 4, 1, 5, 8, 2, 6, 3, 5]

    def run(swa_ring):
        cache = init_cache(cfg, b, 64, swa_ring=swa_ring, ring_headroom=8)
        cl = jnp.zeros((), jnp.int32)
        outs, pos = [], 0
        for nb in blocks:
            lg, cache, _, _ = forward(params, cfg,
                                   {"tokens": toks[:, pos:pos + nb]},
                                   mode="decode", cache=cache, cache_len=cl,
                                   swa_ring=swa_ring)
            outs.append(np.asarray(lg, np.float32))
            cl = cl + nb
            pos += nb
        return np.concatenate(outs, axis=1)

    ref, ring = run(False), run(True)
    err = np.max(np.abs(ref - ring)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 1e-5, err
