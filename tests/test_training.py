"""Training substrate: loss decreases, grad-accum equivalence, optimizer
semantics, checkpoint roundtrip + async + elastic restore."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_config

# end-to-end training loops — the nightly lane's job
pytestmark = pytest.mark.slow
from repro.data import DataConfig, make_pipeline
from repro.dist.elastic import StepWatchdog, elastic_mesh, run_with_restarts
from repro.models import init_model
from repro.training import (AdamWConfig, adamw_update, grad_accum_fn,
                            init_opt_state, loss_fn, lr_schedule,
                            make_train_step)

KEY = jax.random.PRNGKey(0)


def test_loss_decreases_on_synthetic_data():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=50)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, opt_cfg, n_micro=2))
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8))
    losses = []
    for _ in range(50):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.85 * losses[0], (losses[0], losses[-1])


def test_grad_accum_matches_full_batch():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (8, 16), 0, cfg.vocab_size)}
    (_, _), g_full = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, 0.0, False)
    g_acc, _, _ = grad_accum_fn(params, cfg, batch, n_micro=4,
                                aux_weight=0.0, remat=False)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-2, rtol=2e-2)


def test_remat_does_not_change_grads():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    (_, _), g1 = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, 0.0, False)
    (_, _), g2 = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, 0.0, True)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-3)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9          # warmup peak
    assert lrs[100] == pytest.approx(1e-4, rel=1e-3)   # cosine floor
    assert all(lrs[i] >= lrs[i + 1] - 1e-12 for i in range(10, 100))


def test_adamw_weight_decay_masks_norms():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    opt = init_opt_state(params)
    zero_grads = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                              params)
    new_params, _, _ = adamw_update(
        AdamWConfig(lr=1.0, weight_decay=0.5, warmup_steps=0, total_steps=1),
        params, zero_grads, opt)
    # norm scales must be untouched by decay; weights must shrink
    old_scale = np.asarray(params["final_norm"]["scale"], np.float32)
    new_scale = np.asarray(new_params["final_norm"]["scale"], np.float32)
    np.testing.assert_allclose(old_scale, new_scale)
    old_w = np.abs(np.asarray(params["segments"][0]["attn"]["wq"],
                              np.float32)).mean()
    new_w = np.abs(np.asarray(new_params["segments"][0]["attn"]["wq"],
                              np.float32)).mean()
    assert new_w < old_w


def test_checkpoint_roundtrip_and_gc():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            save(d, s, {"params": params}, {"note": s}, keep=2)
        assert latest_step(d) == 4
        kept = [x for x in os.listdir(d) if x.startswith("step_")]
        assert len(kept) == 2                       # GC keeps last 2
        restored, meta = restore(d, {"params": params})
        assert meta["note"] == 4
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(restored["params"])):
            assert np.array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))


def test_async_checkpointer():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=2)
        ck.save(1, {"params": params})
        ck.save(2, {"params": params})      # waits for #1 internally
        ck.wait()
        assert latest_step(d) == 2


def test_elastic_mesh_factorization():
    assert elastic_mesh(512) == ((2, 16, 16), ("pod", "data", "model"))
    assert elastic_mesh(256) == ((16, 16), ("data", "model"))
    shape, axes = elastic_mesh(384)          # degraded fleet
    assert int(np.prod(shape)) == 384
    assert elastic_mesh(8) == ((8, 1), ("data", "model")) or True


def test_run_with_restarts_recovers():
    calls = {"n": 0, "restored": 0}

    def step_fn(step):
        calls["n"] += 1
        if step == 3 and calls["restored"] == 0:
            raise RuntimeError("injected node failure")

    def restore_fn():
        calls["restored"] += 1
        return 2                               # last checkpoint

    final = run_with_restarts(step_fn, 0, 6, restore_fn,
                              retry_transient=False)
    assert final == 6
    assert calls["restored"] == 1


def test_watchdog_flags_persistent_straggler():
    wd = StepWatchdog(deadline_s=1.0, max_misses=2)
    assert not wd.observe(0.5)
    assert not wd.observe(1.5)
    assert wd.observe(1.5)                     # second consecutive miss


def test_binary_shard_pipeline(tmp_path):
    arr = np.arange(4096, dtype=np.uint16) % 100
    (tmp_path / "shard_0.bin").write_bytes(arr.tobytes())
    cfg = DataConfig(vocab_size=100, seq_len=15, global_batch=4,
                     path=str(tmp_path))
    it = make_pipeline(cfg)
    batch = next(it)
    assert batch["tokens"].shape == (4, 15)
    assert batch["tokens"].max() < 100


def test_fractional_remat_preserves_grads():
    """remat=0.5 (perf iteration #3) must be a pure memory/compute
    trade — gradients identical to full remat."""
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    (_, _), g_full = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, 0.0, True)
    (_, _), g_half = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, 0.0, 0.5)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_half)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-3)
