"""Paged KV cache: byte-equality vs dense serving, allocator/COW
invariants, prefix-cache hits, adversarial block-table layouts.

The byte-equality tests run the FULL serving stack (ServingLoop over a
DecodeEngine) twice — dense per-slot cache vs paged pool — and require
identical token streams.  On the kernel path the paged launch's kv tile
is the page size, so the tests pin ``block_size = K_BLOCK`` (128) where
bitwise equality against the dense kernel launch is structural; the
small-page configurations run the XLA reference path, where masked
positions contribute exact zeros and equality is again structural.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.kernels.decode_attention.ops import decode_attention_paged
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.models import init_model
from repro.serving import (BlockManager, DecodeEngine, PagedKVConfig,
                           ServingLoop, init_mtp_heads)

MAX_LEN = 256


@pytest.fixture(scope="module")
def model():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, params, mode, prompts, *, paged=None, use_kernel=False,
           tokens=8, slots=2, max_len=MAX_LEN):
    eng = DecodeEngine(cfg, params, batch=slots, max_len=max_len,
                       use_kernel=use_kernel, paged=paged)
    kwargs = {}
    if mode == "mtp":
        kwargs["mtp_heads"] = init_mtp_heads(
            jax.random.PRNGKey(5), cfg.d_model, cfg.vocab_size, n_heads=4)
    if mode == "diffusion":
        kwargs["refine_steps"] = 2
    loop = ServingLoop(eng, mode=mode, **kwargs)
    for p in prompts:
        loop.submit(p, tokens)
    return loop.run(), loop


def _prompts(cfg, n, seed=3, lo=4, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=int(rng.integers(lo, hi)))
            for _ in range(n)]


# ===========================================================================
# Byte-equality: paged serving == dense serving, all four modes
# ===========================================================================


@pytest.mark.parametrize("mode", ["greedy", "speculative", "mtp",
                                  "diffusion"])
def test_paged_matches_dense_kernel_path(model, mode):
    """Acceptance: paged is byte-identical to dense for every serve mode
    on the Pallas kernel path.  block_size == K_BLOCK makes the paged
    launch's kv tiling identical to the dense launch's, so equality is
    bitwise, not approximate."""
    cfg, params = model
    prompts = _prompts(cfg, 4)
    dense, _ = _serve(cfg, params, mode, prompts, use_kernel=True)
    paged, loop = _serve(cfg, params, mode, prompts, use_kernel=True,
                         paged=PagedKVConfig(block_size=128))
    assert dense.keys() == paged.keys()
    for rid in dense:
        assert np.array_equal(dense[rid], paged[rid]), f"req {rid} diverged"
    # the kernel slack telemetry stays on under paging
    assert any("kv_tile_util" in e for e in loop.step_log)


@pytest.mark.parametrize("mode", ["greedy", "speculative", "mtp",
                                  "diffusion"])
def test_paged_matches_dense_xla_small_pages(model, mode):
    """XLA reference path with small (16-position) pages and fragmented
    allocation: still byte-identical to dense serving."""
    cfg, params = model
    prompts = _prompts(cfg, 5, seed=11)
    dense, _ = _serve(cfg, params, mode, prompts, slots=3)
    paged, _ = _serve(cfg, params, mode, prompts, slots=3,
                      paged=PagedKVConfig(block_size=16))
    for rid in dense:
        assert np.array_equal(dense[rid], paged[rid]), f"req {rid} diverged"


def test_paged_matches_dense_mla(model):
    """MLA's latent cache pages too (XLA path; the kernel serves GQA)."""
    cfg = get_config("minicpm3_4b", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, 3, seed=5)
    dense, _ = _serve(cfg, params, "greedy", prompts, tokens=6)
    paged, _ = _serve(cfg, params, "greedy", prompts, tokens=6,
                      paged=PagedKVConfig(block_size=16))
    for rid in dense:
        assert np.array_equal(dense[rid], paged[rid])


def test_paged_small_pool_backpressure(model):
    """A pool too small for all requests at once stalls admission (free
    BLOCKS gate, not free slots) but still serves every stream
    correctly."""
    cfg, params = model
    prompts = _prompts(cfg, 5, seed=13)
    dense, _ = _serve(cfg, params, "greedy", prompts, slots=3)
    # each request reserves cdiv(p + tokens, 16) <= 2 blocks; 3 blocks
    # force (mostly) serial admission despite 3 free slots
    paged, loop = _serve(cfg, params, "greedy", prompts, slots=3,
                         paged=PagedKVConfig(block_size=16, n_blocks=3))
    for rid in dense:
        assert np.array_equal(dense[rid], paged[rid])
    s = loop.stats()
    assert s["kv_blocks_peak"] <= 3
    assert max(e["active"] for e in loop.step_log) <= 2
    loop.engine.manager.check_invariants()


def test_paged_rejects_unsupported_arch():
    cfg = get_config("falcon_mamba_7b", reduced=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="attention-only"):
        DecodeEngine(cfg, params, batch=2, max_len=64,
                     paged=PagedKVConfig(block_size=16))


def test_paged_block_size_must_divide_max_len(model):
    cfg, params = model
    with pytest.raises(ValueError, match="multiple"):
        DecodeEngine(cfg, params, batch=2, max_len=100,
                     paged=PagedKVConfig(block_size=16))


# ===========================================================================
# Prefix caching
# ===========================================================================


def test_prefix_hit_skips_prefill(model):
    """The second admission of an identical prompt reuses the resident
    blocks: its prefill computes only the divergent suffix (forward
    counters + bucket width shrink), and the output stream is identical
    to dense serving."""
    cfg, params = model
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, size=37)
    dense, _ = _serve(cfg, params, "greedy", [prompt, prompt], slots=1)
    paged, loop = _serve(cfg, params, "greedy", [prompt, prompt], slots=1,
                         paged=PagedKVConfig(block_size=16))
    for rid in dense:
        assert np.array_equal(dense[rid], paged[rid])
    s = loop.stats()
    assert s["prefix_hits"] == 1
    assert s["prefix_hit_tokens"] == 32          # 2 full 16-token blocks
    assert s["prefill_positions_saved"] == 32
    assert s["prefill_positions_computed"] == 37 + 5
    log = loop.engine.prefill_log
    assert log[0]["cached_tokens"] == 0 and log[0]["computed_tokens"] == 37
    assert log[1]["cached_tokens"] == 32 and log[1]["computed_tokens"] == 5
    # the hit admission ran in a (much) narrower bucket than a full
    # prefill would have — the compile/positions win of skipping
    assert log[1]["bucket"] < log[0]["bucket"]
    loop.engine.manager.check_invariants()


def test_prefix_cache_off_never_hits(model):
    cfg, params = model
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, size=37)
    _, loop = _serve(cfg, params, "greedy", [prompt, prompt], slots=1,
                     paged=PagedKVConfig(block_size=16, prefix_cache=False))
    s = loop.stats()
    assert s["prefix_hits"] == 0
    assert s["prefill_positions_saved"] == 0


def test_prefix_hit_with_cow_divergence(model):
    """Prompt length an exact multiple of the block size: the whole
    prompt is cache-resident, the recomputed last position diverges
    INSIDE a shared block, and admission copy-on-writes it."""
    cfg, params = model
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=32)
    dense, _ = _serve(cfg, params, "greedy", [prompt, prompt], slots=1)
    paged, loop = _serve(cfg, params, "greedy", [prompt, prompt], slots=1,
                         paged=PagedKVConfig(block_size=16))
    for rid in dense:
        assert np.array_equal(dense[rid], paged[rid])
    s = loop.stats()
    assert s["prefix_hits"] == 1
    assert s["prefix_hit_tokens"] == 31          # p - 1
    assert s["cow_copies"] == 1
    loop.engine.manager.check_invariants()


def test_prefix_hit_kernel_path(model):
    """Prefix reuse through the Pallas path: hits still fire and streams
    match the no-cache paged serve (identical page-tiled numerics)."""
    cfg, params = model
    rng = np.random.default_rng(21)
    head = rng.integers(0, cfg.vocab_size, size=32)
    prompts = [np.concatenate([head, rng.integers(0, cfg.vocab_size,
                                                  size=4)])
               for _ in range(3)]
    nocache, _ = _serve(cfg, params, "greedy", prompts, slots=1,
                        use_kernel=True,
                        paged=PagedKVConfig(block_size=16,
                                            prefix_cache=False))
    cached, loop = _serve(cfg, params, "greedy", prompts, slots=1,
                          use_kernel=True,
                          paged=PagedKVConfig(block_size=16))
    for rid in nocache:
        assert np.array_equal(nocache[rid], cached[rid])
    assert loop.stats()["prefix_hits"] == 2


# ===========================================================================
# Allocator / refcount / COW invariants (hypothesis)
# ===========================================================================


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_blocks=st.integers(min_value=4, max_value=24),
       block_size=st.sampled_from([4, 8, 16]))
def test_block_manager_invariants(seed, n_blocks, block_size):
    """Random admit/release traffic: refcounts always equal the sum of
    slot + cache holds, free blocks are never referenced, COW only
    fires when the divergence is inside a shared block, and the manager
    refuses (rather than corrupts) when the pool is truly full."""
    rng = np.random.default_rng(seed)
    batch, max_len = 4, 16 * block_size
    mgr = BlockManager(batch, max_len, block_size, n_blocks)
    vocab = 11
    shared = rng.integers(0, vocab, size=int(rng.integers(1, 3 * block_size)))
    live: dict = {}
    for _ in range(30):
        mgr.check_invariants()
        op = rng.random()
        free_slots = [s for s in range(batch) if s not in live]
        if op < 0.55 and free_slots:
            s = int(rng.choice(free_slots))
            if rng.random() < 0.5:
                tail = rng.integers(0, vocab,
                                    size=int(rng.integers(0, block_size)))
                prompt = np.concatenate([shared, tail]).astype(np.int64)
            else:
                prompt = rng.integers(0, vocab,
                                      size=int(rng.integers(1, 2 * block_size)))
            reserve = int(min(len(prompt) + int(rng.integers(0, 16)),
                              max_len))
            reserve = max(reserve, len(prompt))
            cow_before = mgr.cow_copies
            if not mgr.can_admit(prompt.tolist(), reserve):
                with pytest.raises(RuntimeError):
                    mgr.admit(s, prompt.tolist(), reserve)
                # a failed admit may leave a partial table; reset it
                mgr.release(s)
                continue
            res = mgr.admit(s, prompt.tolist(), reserve)
            assert 0 <= res.cached_len <= len(prompt) - 1
            if res.cow_copies:
                # COW only when the divergence sits inside a shared block
                assert res.cached_len % block_size != 0
                assert mgr.cow_copies == cow_before + len(res.cow_copies)
            mgr.register_prompt(s, prompt.tolist())
            live[s] = prompt
        elif live:
            s = int(rng.choice(sorted(live)))
            mgr.release(s)
            del live[s]
    mgr.check_invariants()
    for s in sorted(live):
        mgr.release(s)
    mgr.check_invariants()
    # only the prefix cache may still hold blocks
    held = mgr.allocator.n_used
    assert held == (len(mgr.prefix) if mgr.prefix is not None else 0)


def test_cow_admission_not_gated_on_tight_pool(model):
    """Regression: admission_cost must not double-count the COW source
    (it is decref'd back to evictable before the copy allocates).  On a
    pool exactly the size of one reservation, the second serve of a
    fully cached prompt must still admit — the old accounting gated it
    forever and run() span without serving."""
    bs = 16
    mgr = BlockManager(batch=1, max_len=4 * bs, block_size=bs, n_blocks=4)
    prompt = list(range(2 * bs))                     # fully block-aligned
    mgr.admit(0, prompt, reserve_len=4 * bs)
    mgr.register_prompt(0, prompt)
    mgr.release(0)
    assert mgr.can_admit(prompt, 4 * bs)             # was False (bug)
    res = mgr.admit(0, prompt, reserve_len=4 * bs)
    assert res.cached_len == 2 * bs - 1 and len(res.cow_copies) == 1
    mgr.check_invariants()
    # end-to-end: 1 slot, pool == one reservation, same prompt twice
    cfg, params = model
    rng = np.random.default_rng(17)
    p = rng.integers(0, cfg.vocab_size, size=32)
    eng = DecodeEngine(cfg, params, batch=1, max_len=256,
                       paged=PagedKVConfig(block_size=16, n_blocks=3))
    loop = ServingLoop(eng, mode="greedy")
    loop.submit(p, 8)
    loop.submit(p, 8)
    results = loop.run()
    assert len(results) == 2
    assert np.array_equal(results[0], results[1])
    assert loop.stats()["prefix_hits"] == 1


def test_refcount_sharing_and_eviction():
    """Two slots sharing a cached prefix: the shared blocks carry one
    hold per slot + one for the cache; eviction only recycles blocks
    whose sole hold is the cache's."""
    bs = 8
    mgr = BlockManager(batch=2, max_len=8 * bs, block_size=bs, n_blocks=6)
    prompt = list(range(2 * bs + 3))                   # 2 full blocks
    r0 = mgr.admit(0, prompt, reserve_len=3 * bs)
    assert r0.cached_len == 0 and r0.new_blocks == 3
    mgr.register_prompt(0, prompt)
    r1 = mgr.admit(1, prompt, reserve_len=3 * bs)
    assert r1.cached_len == 2 * bs
    shared = [int(mgr.tables[1, i]) for i in range(2)]
    assert shared == [int(mgr.tables[0, i]) for i in range(2)]
    for b in shared:
        assert mgr.allocator.refcount[b] == 3          # slot0 + slot1 + cache
    mgr.check_invariants()
    mgr.release(0)
    for b in shared:
        assert mgr.allocator.refcount[b] == 2
    mgr.release(1)
    for b in shared:
        assert mgr.allocator.refcount[b] == 1          # cache-only
    assert mgr.n_evictable() == 2
    # exhaust the pool: allocation must evict the cache-only blocks
    free_before = mgr.allocator.n_free
    grabbed = [mgr._alloc_or_evict() for _ in range(free_before + 2)]
    assert mgr.evictions == 2
    assert len(set(grabbed)) == len(grabbed)
    with pytest.raises(RuntimeError):
        mgr._alloc_or_evict()


# ===========================================================================
# Adversarial block-table layouts on the kernel path
# ===========================================================================


def _pool_from_dense(k_dense, v_dense, lens, n, bs, layout, seed=0):
    """Pack a dense (b, s, kv, dh) cache into a pool under ``layout``:
    'fragmented' (random pages), 'reversed' (descending pages),
    'identity' (pages in order)."""
    b, s, kv, dh = k_dense.shape
    max_blocks = s // bs
    rng = np.random.default_rng(seed)
    need = []
    for bi in range(b):
        need.append(-(-int(lens[bi] + n) // bs))
    n_phys = sum(max(c, 1) for c in need) + 2          # + slack + trash
    order = np.arange(n_phys - 1)
    if layout == "fragmented":
        rng.shuffle(order)
    elif layout == "reversed":
        order = order[::-1]
    tables = np.full((b, max_blocks), n_phys - 1, np.int32)
    k_pool = np.asarray(
        rng.standard_normal((n_phys, bs, kv, dh)), np.float32)
    v_pool = np.asarray(
        rng.standard_normal((n_phys, bs, kv, dh)), np.float32)
    pi = 0
    for bi in range(b):
        for j in range(need[bi]):
            p = int(order[pi]); pi += 1
            tables[bi, j] = p
            k_pool[p] = np.asarray(k_dense[bi, j * bs:(j + 1) * bs])
            v_pool[p] = np.asarray(v_dense[bi, j * bs:(j + 1) * bs])
    return jnp.asarray(k_pool), jnp.asarray(v_pool), jnp.asarray(tables)


@pytest.mark.parametrize("layout", ["fragmented", "reversed", "identity"])
@pytest.mark.parametrize("window", [None, 24])
def test_paged_kernel_adversarial_layouts(layout, window):
    """Kernel-vs-oracle parity under hostile tables: scattered and
    reversed physical pages, a len-0 row, a single-block slot, and a
    full-cache row — junk in unattached pages must never leak through."""
    rng = np.random.default_rng(1)
    b, n, h, kv, dh = 4, 4, 8, 2, 64
    bs, s = 16, 96
    lens = np.array([0, 5, 16 - n, s - n], np.int32)   # len-0 / single-block
    q = jnp.asarray(rng.standard_normal((b, n, h, dh)), jnp.float32)
    k_dense = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.float32)
    v_dense = jnp.asarray(rng.standard_normal((b, s, kv, dh)), jnp.float32)
    k_pool, v_pool, tables = _pool_from_dense(k_dense, v_dense, lens, n,
                                              bs, layout)
    out = decode_attention_paged(q, k_pool, v_pool, jnp.asarray(lens),
                                 tables, window=window)
    ref = decode_attention_ref(q, k_dense, v_dense, jnp.asarray(lens),
                               window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ===========================================================================
# Admission rejection (the prefill_bucket clamp bugfix)
# ===========================================================================


@pytest.mark.parametrize("paged", [None, PagedKVConfig(block_size=16)])
def test_submit_rejects_oversized_prompt(model, paged):
    """A prompt longer than max_len is rejected at submit with a clear
    error instead of failing deep inside the clamped prefill bucket."""
    cfg, params = model
    eng = DecodeEngine(cfg, params, batch=2, max_len=64, paged=paged)
    loop = ServingLoop(eng, mode="greedy")
    with pytest.raises(ValueError, match="exceeds the engine's max_len"):
        loop.submit(np.arange(65) % cfg.vocab_size, max_tokens=1)
    with pytest.raises(ValueError, match="cannot fit"):
        loop.submit(np.arange(60) % cfg.vocab_size, max_tokens=16)
    with pytest.raises(ValueError, match="empty"):
        loop.submit(np.zeros((0,), np.int64), max_tokens=4)


def test_prefill_slots_rejects_oversized_prompt(model):
    """The engine-level API guards too (callers that bypass the loop)."""
    cfg, params = model
    eng = DecodeEngine(cfg, params, batch=2, max_len=64)
    with pytest.raises(ValueError, match="exceeds the engine's max_len"):
        eng.prefill_slots({0: jnp.zeros((70,), jnp.int32)})


def test_submit_rejects_request_larger_than_pool(model):
    cfg, params = model
    eng = DecodeEngine(cfg, params, batch=2, max_len=256,
                       paged=PagedKVConfig(block_size=16, n_blocks=4))
    loop = ServingLoop(eng, mode="greedy")
    with pytest.raises(ValueError, match="KV blocks"):
        loop.submit(np.arange(100) % cfg.vocab_size, max_tokens=50)
