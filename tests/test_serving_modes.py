"""Batched diffusion + MTP serving through the scheduler, the diffusion
KV-commit regression, and the bucketed-prefill compile discipline.

Fast lane: tiny reduced configs, short streams — these are the
scheduler-mode goldens the tier-1 suite must keep honest."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serving import (DecodeEngine, DiffusionBlockDecoder, MTPDecoder,
                           ServingLoop, init_mtp_heads)
from repro.serving.diffusion import refine_block
from repro.serving.engine import _prefill_fn

KEY = jax.random.PRNGKey(0)
TOKENS = 10


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("stablelm_3b", reduced=True)
    params = init_model(KEY, cfg)
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(i + 1), (5 + i,), 0, cfg.vocab_size))
        for i in range(4)]
    return cfg, params, prompts


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("llada_mini_like", reduced=True)
    params = init_model(KEY, cfg)
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(i + 1), (5 + i,), 0, cfg.vocab_size))
        for i in range(3)]
    return cfg, params, prompts


def _cache_kv(engine, length):
    """Every attention-segment cache leaf, truncated to ``length``."""
    out = []
    for seg in engine.cache["segments"]:
        for key in sorted(seg):
            out.append(np.asarray(seg[key][:, :, :length]
                                  .astype(jnp.float32)))
    return out


# ===========================================================================
# Headline bugfix: diffusion must not commit KV computed from MASK inputs
# ===========================================================================

def test_diffusion_committed_kv_matches_prefill(dense_setup):
    """After a diffusion generation, the engine cache must be
    byte-identical to PREFILLING the resolved stream — the final
    refinement iteration's cache saw mask-token inputs and must never
    have been committed."""
    cfg, params, prompts = dense_setup
    prompt = jnp.asarray(prompts[2])[None]
    eng = DecodeEngine(cfg, params, batch=1, max_len=96)
    dec = DiffusionBlockDecoder(eng, block_size=5, refine_steps=2)
    toks, _ = dec.generate(prompt, TOKENS)
    stream = np.concatenate([np.asarray(prompt[0]), toks[:-1]])
    assert int(eng.cache_len) == len(stream)
    ref = DecodeEngine(cfg, params, batch=1, max_len=96)
    ref.prefill(jnp.asarray(stream[None], jnp.int32))
    for got, want in zip(_cache_kv(eng, len(stream)),
                         _cache_kv(ref, len(stream))):
        assert np.array_equal(got, want)


class _PoisonedCommit(DiffusionBlockDecoder):
    """The pre-fix resolve: commits the LAST REFINEMENT forward's cache,
    in which positions resolved during/after the final iteration were
    still mask_id inputs."""

    def resolve(self, pending, drafts):
        n = len(drafts)
        block = np.asarray(drafts, np.int64).copy()
        resolved = np.zeros((n,), bool)
        per_iter = max(1, int(np.ceil(n / self.refine_steps)))
        step_logits, new_cache = None, None
        for _ in range(self.refine_steps):
            if resolved.all():
                break
            step_logits, new_cache, _ = self.forward_block(
                np.concatenate([[pending], block]))
            refine_block(block, resolved,
                         np.asarray(step_logits[0].astype(jnp.float32)),
                         per_iter)
        if not resolved.all():
            block[~resolved] = np.asarray(
                jnp.argmax(step_logits[0], axis=-1))[:n][~resolved]
        self.engine.commit(new_cache, n)
        return list(block[:-1]), int(block[-1])


def test_diffusion_kv_regression_has_teeth(dense_setup):
    """Negative control: replaying the pre-fix commit (the cache of a
    forward that still saw MASK inputs) must FAIL the byte comparison —
    i.e. the regression test above genuinely catches the bug."""
    cfg, params, prompts = dense_setup
    prompt = jnp.asarray(prompts[2])[None]
    eng = DecodeEngine(cfg, params, batch=1, max_len=96)
    dec = _PoisonedCommit(eng, block_size=5, refine_steps=2)
    toks, _ = dec.generate(prompt, TOKENS)
    stream = np.concatenate([np.asarray(prompt[0]), toks[:-1]])
    ref = DecodeEngine(cfg, params, batch=1, max_len=96)
    ref.prefill(jnp.asarray(stream[None], jnp.int32))
    assert any(not np.array_equal(got, want)
               for got, want in zip(_cache_kv(eng, len(stream)),
                                    _cache_kv(ref, len(stream))))


# ===========================================================================
# Golden byte-equivalence: batched scheduler modes vs solo drivers
# ===========================================================================

def test_serving_diffusion_matches_solo(dense_setup):
    """ServingLoop(mode='diffusion') over a mixed-length batch: every
    request's token stream is byte-identical to the solo
    DiffusionBlockDecoder at the same block size, including through a
    queue deeper than the slot pool."""
    cfg, params, prompts = dense_setup
    solo = []
    for p in prompts:
        eng = DecodeEngine(cfg, params, batch=1, max_len=96)
        dec = DiffusionBlockDecoder(eng, block_size=4, refine_steps=2)
        toks, _ = dec.generate(jnp.asarray(p)[None], TOKENS)
        solo.append(np.asarray(toks))
    eng = DecodeEngine(cfg, params, batch=3, max_len=96)
    loop = ServingLoop(eng, mode="diffusion", block_size=4, refine_steps=2)
    for p in prompts:
        loop.submit(p, TOKENS)
    out = loop.run()
    assert len(out) == len(prompts)
    for i in range(len(prompts)):
        assert np.array_equal(solo[i], out[i]), i
    # block parallelism realized through the shared forwards
    assert loop.stats()["tokens_per_forward"] > 1.0


def test_serving_mtp_matches_solo(dense_setup):
    """ServingLoop(mode='mtp') is lossless: byte-identical to solo AR
    greedy AND to the solo MTPDecoder (greedy acceptance)."""
    cfg, params, prompts = dense_setup
    heads = init_mtp_heads(jax.random.PRNGKey(5), cfg.d_model,
                           cfg.vocab_size, n_heads=4)
    refs = []
    for p in prompts:
        eng = DecodeEngine(cfg, params, batch=1, max_len=96)
        refs.append(np.asarray(
            eng.greedy_generate(jnp.asarray(p)[None], TOKENS)[0]))
    eng = DecodeEngine(cfg, params, batch=1, max_len=96)
    solo_mtp, _ = MTPDecoder(eng, heads).generate(
        jnp.asarray(prompts[0])[None], TOKENS)
    assert np.array_equal(refs[0], solo_mtp[:TOKENS])
    eng = DecodeEngine(cfg, params, batch=3, max_len=96)
    loop = ServingLoop(eng, mode="mtp", mtp_heads=heads, max_width=5)
    for p in prompts:
        loop.submit(p, TOKENS)
    out = loop.run()
    for i in range(len(prompts)):
        assert np.array_equal(refs[i], out[i]), i


def test_serving_modes_moe_kernel_golden(moe_setup):
    """MoE config through the Pallas ragged decode-attention path
    (use_kernel=True, interpret on CPU): batched diffusion + mtp streams
    stay byte-identical to their solo drivers."""
    cfg, params, prompts = moe_setup
    t = 6
    heads = init_mtp_heads(jax.random.PRNGKey(5), cfg.d_model,
                           cfg.vocab_size, n_heads=3)
    solo_diff, refs = [], []
    for p in prompts:
        eng = DecodeEngine(cfg, params, batch=1, max_len=64,
                           use_kernel=True)
        dec = DiffusionBlockDecoder(eng, block_size=3, refine_steps=2)
        toks, _ = dec.generate(jnp.asarray(p)[None], t)
        solo_diff.append(np.asarray(toks))
        eng = DecodeEngine(cfg, params, batch=1, max_len=64,
                           use_kernel=True)
        refs.append(np.asarray(
            eng.greedy_generate(jnp.asarray(p)[None], t)[0]))
    eng = DecodeEngine(cfg, params, batch=3, max_len=64, use_kernel=True)
    loop = ServingLoop(eng, mode="diffusion", block_size=3, refine_steps=2)
    for p in prompts:
        loop.submit(p, t)
    out = loop.run()
    for i in range(len(prompts)):
        assert np.array_equal(solo_diff[i], out[i]), i
    eng = DecodeEngine(cfg, params, batch=3, max_len=64, use_kernel=True)
    loop = ServingLoop(eng, mode="mtp", mtp_heads=heads, max_width=4)
    for p in prompts:
        loop.submit(p, t)
    out = loop.run()
    for i in range(len(prompts)):
        assert np.array_equal(refs[i], out[i]), i


# ===========================================================================
# Bucketed batched prefill: compile discipline + one forward per group
# ===========================================================================

def test_bucketed_prefill_one_forward_per_admission_group(dense_setup):
    """8 admissions with 8 distinct prompt lengths and 8 free slots:
    ONE prefill forward (not one full-batch forward per request)."""
    cfg, params, _ = dense_setup
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(30 + i), (5 + i,), 0, cfg.vocab_size))
        for i in range(8)]
    eng = DecodeEngine(cfg, params, batch=8, max_len=96)
    loop = ServingLoop(eng, mode="greedy")
    for p in prompts:
        loop.submit(p, 4)
    loop.run()
    assert len(eng.prefill_log) == 1
    assert eng.prefill_log[0]["slots"] == list(range(8))
    assert eng.prefill_log[0]["bucket"] == 16     # next pow2 >= 12


def test_bucketed_prefill_compiles_at_most_n_buckets(dense_setup):
    """M admissions at M distinct prompt lengths trigger at most
    n_buckets prefill compiles — staggered admission through a small
    slot pool included."""
    cfg, params, _ = dense_setup
    lengths = list(range(5, 13))                  # buckets: 8 and 16
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(40 + i), (n,), 0, cfg.vocab_size))
        for i, n in enumerate(lengths)]
    eng = DecodeEngine(cfg, params, batch=2, max_len=96)
    n_buckets = len({eng.prefill_bucket(n) for n in lengths})
    assert n_buckets == 2
    before = _prefill_fn._cache_size()
    loop = ServingLoop(eng, mode="greedy")
    for p in prompts:
        loop.submit(p, 4)
    loop.run()
    compiled = _prefill_fn._cache_size() - before
    assert 0 < compiled <= n_buckets
    used = {e["bucket"] for e in eng.prefill_log}
    assert used <= {8, 16}


def test_commit_slots_row_mask_on_device(dense_setup):
    """commit_slots must leave advance-0 rows untouched and accept the
    advances without a host round-trip (device array in, no np
    materialization required)."""
    cfg, params, prompts = dense_setup
    eng = DecodeEngine(cfg, params, batch=2, max_len=96)
    eng.prefill_slots({0: prompts[0], 1: prompts[1]})
    before = _cache_kv(eng, 32)
    toks = jnp.asarray(np.zeros((2, 2), np.int64), jnp.int32)
    _, new_cache, _ = eng.decode_slots(toks)
    eng.commit_slots(new_cache, jnp.asarray([2, 0], jnp.int32))
    after = _cache_kv(eng, 32)
    lens = np.asarray(eng.slot_lens)
    assert lens[0] == len(prompts[0]) + 2 and lens[1] == len(prompts[1])
    for b, a in zip(before, after):
        # row 1 untouched everywhere; row 0 advanced
        assert np.array_equal(b[:, 1], a[:, 1])
