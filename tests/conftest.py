"""Test-suite bootstrap.

``hypothesis`` is a dev dependency (see pyproject.toml); some CI images
ship without it.  Rather than losing the whole module to a collection
error, install a minimal deterministic fallback into ``sys.modules``:
``@given`` becomes a parameterized sweep over a fixed sample of each
strategy's domain.  The real package always wins when importable.
"""
from __future__ import annotations

import itertools
import sys
import types


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    mod = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    def integers(min_value, max_value):
        lo, hi = int(min_value), int(max_value)
        span = hi - lo
        picks = sorted({lo, lo + span // 3, lo + (2 * span) // 3, hi,
                        min(lo + 1, hi), max(hi - 1, lo)})
        return _Strategy(picks)

    def sampled_from(xs):
        return _Strategy(xs)

    def booleans():
        return _Strategy([False, True])

    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy([lo, (lo + hi) / 2.0, hi])

    def lists(elements, min_size=0, max_size=10, **_kw):
        sizes = sorted({min_size, (min_size + max_size) // 2, max_size})
        es = elements.samples or [0]
        return _Strategy([[es[i % len(es)] for i in range(s)]
                          for s in sizes])

    strategies.integers = integers
    strategies.sampled_from = sampled_from
    strategies.booleans = booleans
    strategies.floats = floats
    strategies.lists = lists

    def given(**strats):
        names = sorted(strats)
        grids = [strats[n].samples for n in names]
        cases = list(itertools.product(*grids))

        def deco(fn):
            def wrapper(*args, **kwargs):
                for combo in cases:
                    fn(*args, **dict(zip(names, combo)), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.HealthCheck = types.SimpleNamespace(too_slow="too_slow")
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_fallback()
