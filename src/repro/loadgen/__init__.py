"""repro.loadgen — seeded trace-driven production-traffic harness.

Three pieces: ``trace`` generates deterministic request traces
(Poisson / MMPP arrivals, heavy-tail length mixes, shared-prefix
fleets, multi-tenant SLO classes), ``harness`` replays a trace through
the REAL ``ServingLoop`` on a virtual clock, and ``stats`` turns the
per-request timelines into TTFT / inter-token-latency percentiles and
goodput-under-SLO.
"""
from repro.loadgen.harness import replay_trace
from repro.loadgen.stats import (RequestRecord, itls, percentile,
                                 summarize, ttft)
from repro.loadgen.trace import (ArrivalSpec, LengthSpec, TenantSpec,
                                 Trace, TraceRequest, TraceSpec,
                                 generate_trace, pinned_spec)

__all__ = ["ArrivalSpec", "LengthSpec", "RequestRecord", "TenantSpec",
           "Trace", "TraceRequest", "TraceSpec", "generate_trace", "itls",
           "percentile", "pinned_spec", "replay_trace", "summarize",
           "ttft"]
