"""Latency statistics for trace replays: TTFT / inter-token latency
percentiles and goodput-under-SLO.

Percentiles come in the two conventions that actually disagree on
small samples (tests pin both against hand-computed fixtures):

  nearest_rank   classic ceil(q/100 * n)-th order statistic — always an
                 observed value, the convention most serving papers
                 report (and the BENCH headline here).
  linear         numpy-default interpolation between closest ranks.

Goodput is the paper-adjacent serving metric: tokens/s counting ONLY
requests that met their SLO class's targets (TTFT <= ttft_target and
p95 inter-token latency <= itl_target) — throughput you could sell.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

__all__ = ["RequestRecord", "itls", "percentile", "summarize", "ttft"]


def percentile(xs: Sequence[float], q: float,
               method: str = "nearest_rank") -> float:
    """q-th percentile (0 <= q <= 100) of ``xs``.

    Raises on an empty sample (a silent 0.0 would fabricate a latency);
    a one-sample list is its own percentile under both methods.
    """
    if not 0 <= q <= 100:
        raise ValueError(f"percentile q={q} outside [0, 100]")
    s = sorted(float(x) for x in xs)
    n = len(s)
    if n == 0:
        raise ValueError("percentile of an empty sample")
    if n == 1:
        return s[0]
    if method == "nearest_rank":
        rank = max(1, math.ceil(q / 100.0 * n))   # 1-indexed
        return s[min(rank, n) - 1]
    if method == "linear":
        pos = q / 100.0 * (n - 1)
        lo = int(math.floor(pos))
        if lo >= n - 1:
            return s[-1]
        frac = pos - lo
        return s[lo] + frac * (s[lo + 1] - s[lo])
    raise ValueError(f"unknown percentile method {method!r}")


@dataclass
class RequestRecord:
    """One request's replay timeline: when it arrived, when each token
    materialized on the virtual clock, and what the loop did to it."""

    rid: int
    slo_class: str
    tenant: str = ""
    arrival_s: float = 0.0
    token_times: List[float] = field(default_factory=list)
    rejected: bool = False
    preemptions: int = 0

    @property
    def n_tokens(self) -> int:
        return len(self.token_times)

    @property
    def first_token_s(self) -> Optional[float]:
        return self.token_times[0] if self.token_times else None

    @property
    def finish_s(self) -> Optional[float]:
        return self.token_times[-1] if self.token_times else None


def ttft(rec: RequestRecord) -> Optional[float]:
    """Time-to-first-token: queue wait + (re)prefill, arrival-relative."""
    if rec.first_token_s is None:
        return None
    return rec.first_token_s - rec.arrival_s


def itls(rec: RequestRecord) -> List[float]:
    """Inter-token latencies (gaps between consecutive emissions).
    Tokens decoded in the same parallel step share a timestamp, so a
    gap of 0.0 is real parallelism, not an artifact."""
    t = rec.token_times
    return [t[i + 1] - t[i] for i in range(len(t) - 1)]


def _met_slo(rec: RequestRecord, slo) -> bool:
    t = ttft(rec)
    if t is None or t > slo.ttft_target_s:
        return False
    gaps = itls(rec)
    if not gaps:                       # single-token stream: TTFT is all
        return True
    return percentile(gaps, 95) <= slo.itl_target_s


def _group(records: Sequence[RequestRecord], classes,
           makespan_s: float) -> Dict:
    done = [r for r in records if not r.rejected and r.token_times]
    out: Dict = {
        "requests": len(records),
        "completed": len(done),
        "rejected": sum(r.rejected for r in records),
        "preemptions": sum(r.preemptions for r in records),
        "tokens": sum(r.n_tokens for r in done),
    }
    span = max(makespan_s, 1e-12)
    out["throughput_tok_s"] = out["tokens"] / span
    if not done:
        out.update({"slo_attainment": None, "goodput_tok_s": 0.0})
        return out
    ttfts = [ttft(r) for r in done]
    gaps = [g for r in done for g in itls(r)]
    for q in (50, 95, 99):
        out[f"ttft_p{q}_s"] = percentile(ttfts, q)
    out["ttft_mean_s"] = sum(ttfts) / len(ttfts)
    for q in (50, 95, 99):
        out[f"itl_p{q}_s"] = percentile(gaps, q) if gaps else None
    met = [r for r in done if _met_slo(r, classes[r.slo_class])]
    out["slo_attainment"] = len(met) / len(done)
    out["goodput_tok_s"] = sum(r.n_tokens for r in met) / span
    return out


def summarize(records: Sequence[RequestRecord], classes,
              makespan_s: float) -> Dict:
    """Overall + per-SLO-class latency/goodput summary.

    ``classes`` maps class name -> ``serving.SLOClass``;
    ``makespan_s`` is the replay's total virtual time (throughput and
    goodput denominators)."""
    out = _group(records, classes, makespan_s)
    out["makespan_s"] = makespan_s
    per = {}
    for name in sorted({r.slo_class for r in records}):
        sub = [r for r in records if r.slo_class == name]
        per[name] = _group(sub, classes, makespan_s)
    out["per_class"] = per
    return out
