"""Replay a trace through the REAL ``ServingLoop`` on a virtual clock.

Open-loop replay: requests become visible to the loop when the virtual
clock reaches their ``arrival_s`` — never earlier, so queueing delay,
backpressure rejections, and preemption pressure emerge from the trace
shape rather than from submitting everything up front.

The clock is whatever the loop itself runs on.  Each decode step's
``step_latency_s`` (wall seconds on a real accelerator, or the
injected ``step_clock`` roofline model on a CPU host — see
``benchmarks.calibration``) advances time; prefill forwards are priced
per bucketed ``prefill_log`` entry through the same ``step_clock``
(bucket positions at bucket context), or by wall time around ``admit``
when no model clock is injected.  With a model clock the whole replay
is DETERMINISTIC: two same-seed runs produce byte-identical metrics
(the BENCH determinism gate).

Token timestamps: every token a request has accumulated by the end of
a step/admission materializes at that boundary's clock reading — the
first token at (re)prefill completion, so TTFT = queue wait + prefill,
and parallel-decoded tokens of one step share a timestamp (ITL 0.0
gaps are real parallelism).
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.loadgen.stats import RequestRecord, summarize
from repro.loadgen.trace import Trace
from repro.serving import AdmissionRejected, ServingLoop

__all__ = ["replay_trace"]


def replay_trace(loop: ServingLoop, trace: Trace,
                 max_virtual_s: Optional[float] = None) -> Dict:
    """Drive ``loop`` with ``trace``; returns a report dict with the
    per-request ``records``, the ``summarize`` metrics, the loop's own
    ``stats`` and the final token streams (trace-rid keyed, for the
    byte-equivalence goldens)."""
    records = {t.rid: RequestRecord(
        rid=t.rid, slo_class=t.slo_class, tenant=t.tenant,
        arrival_s=t.arrival_s) for t in trace.requests}
    handles: Dict[int, int] = {}             # loop rid -> trace rid
    eng = loop.engine
    clocked = loop.step_clock is not None
    now = 0.0
    i = 0
    pending = list(trace.requests)

    def drain(at: float) -> None:
        """Timestamp every newly materialized token at ``at``."""
        for loop_rid, trace_rid in handles.items():
            req = loop.finished.get(loop_rid)
            if req is None:
                for r in loop.active.values():
                    if r.rid == loop_rid:
                        req = r
                        break
            if req is None:                   # still waiting / preempted
                for r in loop.waiting:
                    if r.rid == loop_rid:
                        req = r
                        break
            if req is None:
                continue
            rec = records[trace_rid]
            n = int(req.tokens().shape[0])
            while rec.n_tokens < n:
                rec.token_times.append(at)
            rec.preemptions = req.preemptions

    while True:
        # --- arrivals due by ``now`` enter the loop's queue ------------
        while i < len(pending) and pending[i].arrival_s <= now + 1e-12:
            t = pending[i]
            i += 1
            try:
                req = loop.submit(np.asarray(t.prompt, np.int64),
                                  t.max_tokens, slo_class=t.slo_class)
                handles[req.rid] = t.rid
            except AdmissionRejected:
                records[t.rid].rejected = True
        # --- admission (prefill cost advances the clock) ---------------
        pmark = len(eng.prefill_log)
        t0 = time.perf_counter()
        loop.admit()
        if clocked:
            for e in eng.prefill_log[pmark:]:
                b = max(int(e["bucket"]), 1)
                now += loop.step_clock(b, b)
        else:
            now += time.perf_counter() - t0
        drain(now)                        # first tokens land at prefill end
        if not loop.active:
            if i < len(pending):
                if loop.waiting:
                    raise RuntimeError(
                        "replay stalled: waiting requests cannot be "
                        "admitted and nothing is active to retire")
                now = max(now, pending[i].arrival_s)   # idle-skip
                continue
            if loop.waiting:
                raise RuntimeError(
                    "replay stalled with requests still waiting")
            break                                       # fully drained
        # --- one decode step -------------------------------------------
        smark = len(loop.step_log)
        loop.step()
        now += sum(e.get("step_latency_s", 0.0)
                   for e in loop.step_log[smark:])
        drain(now)
        if max_virtual_s is not None and now > max_virtual_s:
            break

    streams = {handles[r]: loop.finished[r].tokens()
               for r in loop.finished if r in handles}
    recs = [records[t.rid] for t in trace.requests]
    return {
        "clock": "simulated" if clocked else "wall",
        "makespan_s": now,
        "metrics": summarize(recs, {
            n: loop.admission.slo(n)
            for n in {r.slo_class for r in recs}}, now),
        "records": recs,
        "serving": loop.stats(),
        "streams": streams,
        "trace_fingerprint": trace.fingerprint(),
    }
