"""Seeded, deterministic production-shaped traces.

A trace is a list of ``TraceRequest`` rows (arrival time, prompt
tokens, output length, tenant, SLO class) generated from a
``TraceSpec`` by ONE ``np.random.default_rng(seed)`` stream, so the
same spec always yields the byte-identical trace (the property tests
lock this down, and ``Trace.fingerprint`` pins it in BENCH artifacts).

Production shape, not microbenchmark shape:

  arrivals   Poisson (memoryless, the classic open-loop model) or a
             2-state MMPP (Markov-modulated Poisson: calm/burst rates
             with switch probabilities) for the bursty traffic that
             actually stresses admission control and preemption.
  lengths    heavy-tail mixes — bounded Pareto (tail index ``alpha``)
             or clamped lognormal — because production prompt/output
             lengths are famously not uniform: a fat tail of long
             requests is what fragments the KV pool.
  tenants    weighted multi-tenant mix; each tenant carries an SLO
             class (``repro.serving.DEFAULT_SLO_CLASSES`` names) and
             optionally a SHARED PREFIX: a per-tenant system-prompt
             token block reused (with probability ``share_prob``) at
             the head of its requests, so replays exercise the paged
             engine's prefix cache the way fleet traffic does.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Tuple

import numpy as np

__all__ = ["ArrivalSpec", "LengthSpec", "TenantSpec", "Trace",
           "TraceRequest", "TraceSpec", "generate_trace", "pinned_spec"]


@dataclass(frozen=True)
class ArrivalSpec:
    """Arrival process: ``poisson`` (rate_rps) or 2-state ``mmpp``
    (calm ``rate_rps`` / ``burst_rate_rps``, per-arrival switch
    probabilities)."""

    kind: str = "poisson"
    rate_rps: float = 8.0
    burst_rate_rps: float = 40.0
    p_enter_burst: float = 0.1
    p_exit_burst: float = 0.3


@dataclass(frozen=True)
class LengthSpec:
    """Token-length distribution clamped to [lo, hi]: ``pareto``
    (bounded, tail index ``alpha``), ``lognormal`` (``mu``/``sigma`` in
    log-token space), or ``fixed`` (always ``lo``)."""

    dist: str = "pareto"
    lo: int = 4
    hi: int = 64
    alpha: float = 1.2
    mu: float = 2.0
    sigma: float = 0.6


@dataclass(frozen=True)
class TenantSpec:
    """One tenant: selection ``weight``, SLO class, and an optional
    shared prefix of ``shared_prefix_len`` tokens prepended (with
    probability ``share_prob``) to its prompts."""

    name: str
    slo_class: str = "default"
    weight: float = 1.0
    shared_prefix_len: int = 0
    share_prob: float = 1.0


@dataclass(frozen=True)
class TraceRequest:
    """One traced request; ``rid`` is the arrival index."""

    rid: int
    arrival_s: float
    prompt: Tuple[int, ...]
    max_tokens: int
    tenant: str
    slo_class: str


@dataclass(frozen=True)
class TraceSpec:
    seed: int = 0
    n_requests: int = 32
    vocab_size: int = 1024
    arrivals: ArrivalSpec = field(default_factory=ArrivalSpec)
    prompt_lens: LengthSpec = field(default_factory=LengthSpec)
    output_lens: LengthSpec = field(
        default_factory=lambda: LengthSpec(dist="lognormal", lo=2, hi=32))
    tenants: Tuple[TenantSpec, ...] = (TenantSpec("default"),)


@dataclass(frozen=True)
class Trace:
    spec: TraceSpec
    requests: Tuple[TraceRequest, ...]

    def to_json(self) -> str:
        """Canonical JSON (sorted keys, no whitespace variance) — the
        byte string ``fingerprint`` hashes."""
        return json.dumps(asdict(self), sort_keys=True,
                          separators=(",", ":"))

    @staticmethod
    def from_json(text: str) -> "Trace":
        d = json.loads(text)
        s = d["spec"]
        spec = TraceSpec(
            seed=s["seed"], n_requests=s["n_requests"],
            vocab_size=s["vocab_size"],
            arrivals=ArrivalSpec(**s["arrivals"]),
            prompt_lens=LengthSpec(**s["prompt_lens"]),
            output_lens=LengthSpec(**s["output_lens"]),
            tenants=tuple(TenantSpec(**t) for t in s["tenants"]))
        reqs = tuple(TraceRequest(
            rid=r["rid"], arrival_s=r["arrival_s"],
            prompt=tuple(r["prompt"]), max_tokens=r["max_tokens"],
            tenant=r["tenant"], slo_class=r["slo_class"])
            for r in d["requests"])
        return Trace(spec, reqs)

    def fingerprint(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]


# ----------------------------------------------------------------------
def _sample_gap(rng: np.random.Generator, spec: ArrivalSpec,
                state: List[bool]) -> float:
    """One inter-arrival gap; ``state`` is the MMPP burst flag (boxed
    so the caller's state threads through)."""
    if spec.kind == "poisson":
        return float(rng.exponential(1.0 / spec.rate_rps))
    if spec.kind != "mmpp":
        raise ValueError(f"unknown arrival kind {spec.kind!r}")
    rate = spec.burst_rate_rps if state[0] else spec.rate_rps
    gap = float(rng.exponential(1.0 / rate))
    flip = float(rng.random())
    if state[0]:
        state[0] = flip >= spec.p_exit_burst
    else:
        state[0] = flip < spec.p_enter_burst
    return gap


def _sample_len(rng: np.random.Generator, spec: LengthSpec) -> int:
    lo, hi = int(spec.lo), int(spec.hi)
    if lo > hi:
        raise ValueError(f"LengthSpec lo={lo} > hi={hi}")
    if spec.dist == "fixed" or lo == hi:
        return lo
    if spec.dist == "pareto":
        # bounded-Pareto inverse CDF on [lo, hi], tail index alpha
        u = float(rng.random())
        a = float(spec.alpha)
        ratio = (lo / hi) ** a
        x = lo / (1.0 - u * (1.0 - ratio)) ** (1.0 / a)
    elif spec.dist == "lognormal":
        x = float(rng.lognormal(spec.mu, spec.sigma))
    else:
        raise ValueError(f"unknown length dist {spec.dist!r}")
    return int(min(hi, max(lo, round(x))))


def generate_trace(spec: TraceSpec) -> Trace:
    """Materialize ``spec`` with one seeded RNG stream (fully
    deterministic: same spec -> byte-identical trace)."""
    if not spec.tenants:
        raise ValueError("TraceSpec needs at least one tenant")
    rng = np.random.default_rng(spec.seed)
    # per-tenant shared prefixes, drawn up front in declaration order
    prefixes: Dict[str, np.ndarray] = {}
    for t in spec.tenants:
        if t.shared_prefix_len > 0:
            prefixes[t.name] = rng.integers(
                0, spec.vocab_size, size=t.shared_prefix_len)
    weights = np.asarray([t.weight for t in spec.tenants], float)
    if weights.sum() <= 0:
        raise ValueError("tenant weights must sum > 0")
    weights = weights / weights.sum()
    burst = [False]
    now = 0.0
    requests = []
    for rid in range(spec.n_requests):
        now += _sample_gap(rng, spec.arrivals, burst)
        tenant = spec.tenants[int(rng.choice(len(spec.tenants), p=weights))]
        p_len = _sample_len(rng, spec.prompt_lens)
        n_out = _sample_len(rng, spec.output_lens)
        prefix = prefixes.get(tenant.name)
        share = prefix is not None and float(rng.random()) < tenant.share_prob
        if share and p_len > len(prefix):
            # shared head + a unique tail (>= 1 token, so streams and
            # prefix-cache suffixes still differ per request)
            tail = rng.integers(0, spec.vocab_size,
                                size=p_len - len(prefix))
            prompt = np.concatenate([prefix, tail])
        else:
            prompt = rng.integers(0, spec.vocab_size, size=p_len)
        requests.append(TraceRequest(
            rid=rid, arrival_s=round(now, 9),
            prompt=tuple(int(x) for x in prompt),
            max_tokens=n_out, tenant=tenant.name,
            slo_class=tenant.slo_class))
    return Trace(spec, tuple(requests))


def pinned_spec(seed: int = 20260808, n_requests: int = 32,
                vocab_size: int = 1024,
                max_prompt: int = 48, max_output: int = 16,
                rate_rps: float = 60.0) -> TraceSpec:
    """The pinned BENCH trace shape: bursty MMPP arrivals, heavy-tail
    lengths, an interactive tenant, a shared-prefix fleet tenant (its
    16-token prefix fills a whole block_size=16 KV block, so replays
    hit the prefix cache), and a batch tenant that preemption can
    victimize.  Arrival rates sit near the simulated TPU service rate
    so bursts actually build queue pressure.  ``benchmarks.
    load_harness`` replays exactly this spec; tests pin its fingerprint
    indirectly through BENCH_serving.json."""
    return TraceSpec(
        seed=seed, n_requests=n_requests, vocab_size=vocab_size,
        arrivals=ArrivalSpec(kind="mmpp", rate_rps=rate_rps,
                             burst_rate_rps=4 * rate_rps,
                             p_enter_burst=0.2, p_exit_burst=0.3),
        prompt_lens=LengthSpec(dist="pareto", lo=6, hi=max_prompt,
                               alpha=1.2),
        output_lens=LengthSpec(dist="lognormal", lo=2, hi=max_output,
                               mu=1.8, sigma=0.5),
        tenants=(
            TenantSpec("chat", slo_class="interactive", weight=3.0),
            TenantSpec("fleet", slo_class="default", weight=4.0,
                       shared_prefix_len=16, share_prob=0.9),
            TenantSpec("offline", slo_class="batch", weight=2.0),
        ))
