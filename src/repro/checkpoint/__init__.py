"""repro.checkpoint — fault-tolerant checkpointing."""
from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore, save)

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]
