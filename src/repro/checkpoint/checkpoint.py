"""Fault-tolerant checkpointing for sharded pytrees.

Design (1000+ node posture, DESIGN.md §8):
  - step-numbered directories, atomic finalize via rename of a COMMIT
    marker — a crash mid-write can never produce a "latest" that is
    unreadable;
  - double-buffered async writes (background thread) so the train loop
    is not blocked on IO;
  - keep-last-k GC;
  - restore is mesh-agnostic: arrays are stored logically (host-gathered
    here; per-shard in a true multi-host run) and re-sharded on load with
    whatever mesh the restarted job brings — this is the elastic-scaling
    path: checkpoints written on 512 chips restore onto 256 or 1024.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

COMMIT = "COMMITTED"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any, metadata: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Synchronous checkpoint write with atomic commit."""
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    # bf16 leaves stored as uint16 views (np has no bfloat16)
    dtypes = {}
    arrays = {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            arrays[k] = v
            dtypes[k] = str(v.dtype)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "dtypes": dtypes,
                   "metadata": metadata or {}}, f)
    with open(os.path.join(tmp, COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(d):
        shutil.rmtree(d)
    os.rename(tmp, d)
    _gc(ckpt_dir, keep)
    return d


class AsyncCheckpointer:
    """Double-buffered background writer: snapshot on the caller thread
    (device->host copy), serialize on a worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree, metadata,
                               self.keep), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and os.path.exists(os.path.join(ckpt_dir, d, COMMIT)))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")
             and os.path.exists(os.path.join(ckpt_dir, d, COMMIT))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_tree: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target_tree``; optionally re-shard
    with a (possibly different) mesh's shardings — the elastic path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    for path, old_leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        arr = arrays[key]
        if meta["dtypes"][key] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        leaves.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, meta["metadata"]
