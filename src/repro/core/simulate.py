"""Deterministic roofline + granularity latency simulator.

The CPU container cannot time TPU kernels, so the framework carries the
paper's own performance model in executable form: per-module
``T = max(FLOPs/phi, bytes/beta)`` (Eq. 5-6 rooflines) where FLOPs are the
*physical padded* FLOPs produced by the very same block-selection rules the
Pallas kernels use (``core.granularity``).  Summing modules reproduces the
sequential-execution assumption of the paper (Sec. 4, Limitations).

The simulator serves three roles:
  1. "measured" T(N) curves for NFP boundary extraction on TPU-scale shapes
     (benchmarks/),
  2. the MODEL-side roofline for EXPERIMENTS.md §Roofline cross-checks,
  3. the budget planner backend for serving.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.arch import (LAYER_ATTN, LAYER_HYBRID, LAYER_SSM, ArchConfig)
from repro.core.granularity import (GranularitySpec, cdiv, moe_padded_tokens,
                                    mxu_padded_rows, round_up,
                                    select_q_block, select_scan_chunk,
                                    select_token_block)
from repro.core.hardware import BYTES_BF16, HardwareSpec
from repro.core.nfp import ETA_COMBINE


# Per-module launch/dispatch floor (kernel launch + DMA warmup).  Matches
# the paper's observation that sub-ms module latencies sit on an overhead
# floor (App. I footnote on FlashInfer short-L noise).
MODULE_OVERHEAD_S = 5e-6


@dataclass
class ModuleCost:
    name: str
    flops: float            # physical (padded) FLOPs
    logical_flops: float    # algorithmic FLOPs (no padding)
    bytes: float            # HBM traffic (block-quantized for kernels)

    def time(self, hw: HardwareSpec) -> float:
        return MODULE_OVERHEAD_S + max(self.flops / hw.phi,
                                       self.bytes / hw.beta)

    def bound(self, hw: HardwareSpec) -> str:
        return "compute" if self.flops / hw.phi >= self.bytes / hw.beta else "memory"


@dataclass
class ForwardCost:
    modules: List[ModuleCost]

    def time(self, hw: HardwareSpec) -> float:
        return sum(m.time(hw) for m in self.modules)

    @property
    def flops(self) -> float:
        return sum(m.flops for m in self.modules)

    @property
    def logical_flops(self) -> float:
        return sum(m.logical_flops for m in self.modules)

    @property
    def bytes(self) -> float:
        return sum(m.bytes for m in self.modules)

    def limiting_module(self, hw: HardwareSpec) -> str:
        return max(self.modules, key=lambda m: m.time(hw)).name


# ===========================================================================
# Per-module cost builders (decode forward: b requests x N positions over a
# cache of length L).  s = bf16 bytes.
# ===========================================================================

def _gemm_module(name: str, rows: int, params: int, s: int,
                 pad_rows: Optional[int] = None) -> ModuleCost:
    """Weight-stationary GEMM: FLOPs = 2*rows*params, traffic ~= weights."""
    prows = pad_rows if pad_rows is not None else mxu_padded_rows(rows, s)
    return ModuleCost(
        name=name,
        flops=2.0 * prows * params,
        logical_flops=2.0 * rows * params,
        bytes=float(params) * s + 2.0 * rows * s,  # weights + tiny act r/w
    )


def attention_core_cost(cfg: ArchConfig, b: int, n: int, ell: int,
                        gran: GranularitySpec, s: int = BYTES_BF16) -> ModuleCost:
    """Work quantization (paper App. F): every executed q tile streams the
    WHOLE KV cache through VMEM — so both FLOPs and KV traffic scale with
    ceil(N/q_block), which is what makes the latency staircase survive in
    the memory-bound regime (Fig. 3a)."""
    a = cfg.attention
    ell_eff = min(ell, a.window) if (a.kind == "swa" and a.window) else ell
    d_qk, d_v = a.score_dims
    qb = select_q_block(n, a.head_dim, gran.attn_policy)
    n_tiles = cdiv(n, qb)
    n_pad = n_tiles * qb
    flops = 2.0 * b * n_pad * ell_eff * a.n_heads * (d_qk + d_v)
    logical = 2.0 * b * n * ell_eff * a.n_heads * (d_qk + d_v)
    kv_bytes = b * n_tiles * (ell_eff + n) * a.kv_cache_bytes_per_token
    qo_bytes = b * n * a.n_heads * (d_qk + d_v) * s
    return ModuleCost("attn_core", flops, logical, kv_bytes + qo_bytes)


def attention_proj_cost(cfg: ArchConfig, b: int, n: int,
                        s: int = BYTES_BF16) -> ModuleCost:
    params = cfg._attn_params()
    return _gemm_module("attn_proj", b * n, params, s)


def dense_ffn_cost(cfg: ArchConfig, b: int, n: int,
                   s: int = BYTES_BF16) -> ModuleCost:
    mats = 3 if cfg.ffn.activation == "swiglu" else 2
    params = mats * cfg.d_model * cfg.ffn.d_ff
    return _gemm_module("dense_ffn", b * n, params, s)


def moe_ffn_cost(cfg: ArchConfig, b: int, n: int, gran: GranularitySpec,
                 routing: str = "balanced", s: int = BYTES_BF16,
                 eta: int = ETA_COMBINE) -> ModuleCost:
    """Work quantization (paper App. E): the kernel config (token_block) is
    selected from the TOKEN count (vLLM Table 8: M <= E branch), and every
    executed token-block re-reads its expert's full weights — both FLOPs
    and weight traffic are staircases in ceil(m_e / token_block)."""
    f = cfg.ffn
    e, k = f.n_experts, f.top_k
    t = b * n                         # logical tokens
    total_slots = t * k
    if routing == "balanced":
        basen = total_slots // e
        rem = total_slots % e
        tokens_per_expert = [basen + (1 if i < rem else 0) for i in range(e)]
    else:                             # skewed: all tokens on the same k experts
        tokens_per_expert = [t] * k + [0] * (e - k)
    tb = select_token_block(t, e)     # tau branch keys on tokens (Table 8)
    padded = moe_padded_tokens(tokens_per_expert, tb)
    n_blocks = padded // tb if tb else 0
    e_act = sum(1 for x in tokens_per_expert if x > 0)
    mats = 3 if f.activation == "swiglu" else 2
    per_expert_params = mats * cfg.d_model * f.d_ff
    flops = 2.0 * padded * per_expert_params
    logical = 2.0 * total_slots * per_expert_params
    if t <= e:
        # decode regime (small-M branch): the block-major grouped kernel —
        # every token-block streams its expert's full weights (no reuse
        # across parallel compute units); this is the traffic staircase
        # behind the paper's memory-bound MoE latency steps.
        w_bytes = float(n_blocks) * per_expert_params * s
    else:
        # train/prefill regime (large-M branch): weight-stationary grouped
        # GEMM (ragged_dot) — weights stream once per active expert.
        w_bytes = float(e_act) * per_expert_params * s
    a_bytes = t * cfg.d_model * s * (1 + 3 * k + eta * k)   # Eq. 17
    return ModuleCost("moe_ffn", flops, logical, w_bytes + a_bytes)


def ssm_cost(cfg: ArchConfig, b: int, n: int, gran: GranularitySpec,
             s: int = BYTES_BF16) -> ModuleCost:
    m = cfg.ssm
    d = cfg.d_model
    di = m.d_inner(d)
    params = cfg._ssm_params()
    chunk = select_scan_chunk(n)
    n_pad = round_up(n, chunk)
    proj_flops = 2.0 * b * n_pad * params
    rec_flops = 6.0 * b * n_pad * di * m.d_state   # recurrence (no weights)
    logical = 2.0 * b * n * params + 6.0 * b * n * di * m.d_state
    # weights once + state read/write once per forward
    state_bytes = 2.0 * b * di * m.d_state * 4     # f32 state
    return ModuleCost("ssm", proj_flops + rec_flops, logical,
                      params * s + state_bytes)


def lm_head_cost(cfg: ArchConfig, b: int, n: int,
                 s: int = BYTES_BF16) -> ModuleCost:
    params = cfg.d_model * cfg.vocab_size
    return _gemm_module("lm_head", b * n, params, s)


def embed_cost(cfg: ArchConfig, b: int, n: int,
               s: int = BYTES_BF16) -> ModuleCost:
    byt = b * n * cfg.d_model * s * 2
    return ModuleCost("embed", 0.0, 0.0, byt)


# ===========================================================================
# Full decode forward
# ===========================================================================

def decode_forward_cost(cfg: ArchConfig, b: int, n: int, ell: int,
                        gran: Optional[GranularitySpec] = None,
                        routing: str = "balanced") -> ForwardCost:
    """Cost of one multi-position decode forward: N positions per request,
    batch b, cache length L.  Modules execute sequentially (paper Sec. 4)."""
    if gran is None:
        head_dim = cfg.attention.head_dim if cfg.attention else 128
        gran = GranularitySpec.for_backend(cfg.ffn.n_experts,
                                           head_dim=head_dim)
    mods: List[ModuleCost] = [embed_cost(cfg, b, n)]
    agg: Dict[str, ModuleCost] = {}

    def add(mc: ModuleCost):
        if mc.name in agg:
            prev = agg[mc.name]
            prev.flops += mc.flops
            prev.logical_flops += mc.logical_flops
            prev.bytes += mc.bytes
        else:
            agg[mc.name] = mc

    for kind in cfg.pattern():
        if kind in (LAYER_ATTN, LAYER_HYBRID):
            add(attention_proj_cost(cfg, b, n))
            add(attention_core_cost(cfg, b, n, ell, gran))
        if kind == LAYER_ATTN:
            if cfg.ffn.kind == "dense":
                add(dense_ffn_cost(cfg, b, n))
            elif cfg.ffn.kind == "moe":
                add(moe_ffn_cost(cfg, b, n, gran, routing))
        if kind in (LAYER_SSM, LAYER_HYBRID):
            add(ssm_cost(cfg, b, n, gran))
    mods.extend(agg.values())
    mods.append(lm_head_cost(cfg, b, n))
    return ForwardCost(mods)


def attention_full_cost(cfg: ArchConfig, b: int, s: int,
                        dtype_bytes: int = BYTES_BF16) -> ModuleCost:
    """Full causal self-attention over s positions (train / prefill):
    score+AV FLOPs ~ b*s^2/2; IO ~ activations (flash-style, no s^2
    materialization)."""
    a = cfg.attention
    d_qk, d_v = a.score_dims
    if a.kind == "swa" and a.window and a.window < s:
        # windowed: each query sees at most `window` keys
        flops = 2.0 * b * s * a.window * a.n_heads * (d_qk + d_v)
    else:
        # causal: sum_{q=1..s} q = s(s+1)/2 key positions
        flops = 1.0 * b * s * (s + 1) * a.n_heads * (d_qk + d_v)
    io = b * s * (a.kv_cache_bytes_per_token
                  + 2 * a.n_heads * (d_qk + d_v) * dtype_bytes)
    return ModuleCost("attn_core", flops, flops, io)


def full_forward_cost(cfg: ArchConfig, b: int, s: int,
                      gran: Optional[GranularitySpec] = None,
                      routing: str = "balanced") -> ForwardCost:
    """One full-sequence forward (prefill / the forward half of a train
    step): b sequences of s tokens."""
    if gran is None:
        head_dim = cfg.attention.head_dim if cfg.attention else 128
        gran = GranularitySpec.for_backend(cfg.ffn.n_experts,
                                           head_dim=head_dim)
    mods: List[ModuleCost] = [embed_cost(cfg, b, s)]
    agg: Dict[str, ModuleCost] = {}

    def add(mc: ModuleCost):
        if mc.name in agg:
            prev = agg[mc.name]
            prev.flops += mc.flops
            prev.logical_flops += mc.logical_flops
            prev.bytes += mc.bytes
        else:
            agg[mc.name] = mc

    for kind in cfg.pattern():
        if kind in (LAYER_ATTN, LAYER_HYBRID):
            add(attention_proj_cost(cfg, b, s))
            add(attention_full_cost(cfg, b, s))
        if kind == LAYER_ATTN:
            if cfg.ffn.kind == "dense":
                add(dense_ffn_cost(cfg, b, s))
            elif cfg.ffn.kind == "moe":
                add(moe_ffn_cost(cfg, b, s, gran, routing))
        if kind in (LAYER_SSM, LAYER_HYBRID):
            add(ssm_cost(cfg, b, s, gran))
    mods.extend(agg.values())
    mods.append(lm_head_cost(cfg, b, s))
    return ForwardCost(mods)


def train_step_cost(cfg: ArchConfig, global_batch: int, seq: int,
                    gran: Optional[GranularitySpec] = None,
                    remat: bool = True, n_micro: int = 1,
                    s: int = BYTES_BF16) -> ForwardCost:
    """One optimizer step: fwd + bwd (+ remat recompute) + AdamW update.

    FLOPs: bwd ~= 2x fwd; remat re-runs the fwd during bwd -> 4x total.
    Bytes: per microbatch the weights stream once fwd + twice bwd (dgrad +
    wgrad reads), activations ~2x fwd IO; optimizer adds f32 master/m/v
    read+write (24 B/param) + f32 grads (8 B/param).
    """
    fwd = full_forward_cost(cfg, global_batch, seq, gran)
    mult = 4.0 if remat else 3.0
    params = cfg.param_count()
    weight_bytes = params * s
    opt_bytes = params * (24.0 + 8.0)
    mods = [ModuleCost(m.name, m.flops * mult, m.logical_flops * mult,
                       m.bytes * 3.0) for m in fwd.modules]
    # optimizer update flops ~ 10 flops/param
    mods.append(ModuleCost("adamw", 10.0 * params, 10.0 * params,
                           opt_bytes))
    # extra weight re-reads across microbatches (beyond the 3x above)
    if n_micro > 1:
        mods.append(ModuleCost("microbatch_weight_restream", 0.0, 0.0,
                               (n_micro - 1) * 3.0 * weight_bytes))
    return ForwardCost(mods)


def latency_curve(cfg: ArchConfig, hw: HardwareSpec, b: int, ell: int,
                  n_values, gran: Optional[GranularitySpec] = None,
                  routing: str = "balanced") -> List[Tuple[int, float]]:
    """Simulated T(N) sweep — the TPU-target substitute for CUDA-event
    timing (DESIGN.md §5)."""
    return [(int(n), decode_forward_cost(cfg, b, int(n), ell, gran, routing)
             .time(hw)) for n in n_values]


def module_latency_curve(module_fn, hw: HardwareSpec, n_values) -> List[Tuple[int, float]]:
    """T(N) sweep for a single module-cost builder (module-level analysis)."""
    return [(int(n), module_fn(int(n)).time(hw)) for n in n_values]
