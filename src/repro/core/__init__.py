"""repro.core — the paper's contribution: Near-Free Parallelism (NFP).

Public API:
  hardware:    HardwareSpec, TPU_V5E, H20/A800/H800, get_hardware
  arch:        ArchConfig, AttentionSpec, FFNSpec, SSMSpec, ShapeSpec
  granularity: GranularitySpec, select_q_block, select_token_block, ...
  nfp:         idle-compute baselines + NFP principle predictors
  simulate:    roofline+granularity latency simulator
  measure:     T(N) sweep + N_max(eps) extraction protocol
"""
from repro.core.arch import (LAYER_ATTN, LAYER_HYBRID, LAYER_SSM, LM_SHAPES,
                             ArchConfig, AttentionSpec, EncoderSpec, FFNSpec,
                             ShapeSpec, SSMSpec, shape_applicable)
from repro.core.granularity import (GranularitySpec, attn_padded_q, cdiv,
                                    m_attn, m_moe, moe_padded_tokens,
                                    moe_tau, round_up, select_q_block,
                                    select_scan_chunk, select_token_block)
from repro.core.hardware import (BYTES_BF16, H20, H800, A800, TPU_V5E,
                                 HardwareSpec, get_hardware)
from repro.core.measure import (LatencyCurve, balanced_moe_baseline_n,
                                extract_nmax, sensitivity_sweep,
                                staircase_boundaries, sweep_callable,
                                time_callable)
from repro.core.nfp import (NFPPrediction, ai_attn, ai_dense, ai_moe,
                            n_idle_attn, n_idle_attn_general, n_idle_dense,
                            n_idle_moe, n_idle_ssm, parallelism_budget,
                            predict_dense, predict_model,
                            predict_moe_balanced, predict_moe_skewed)
from repro.core.simulate import (ForwardCost, ModuleCost,
                                 attention_core_cost, decode_forward_cost,
                                 dense_ffn_cost, latency_curve,
                                 module_latency_curve, moe_ffn_cost,
                                 ssm_cost)

__all__ = [n for n in dir() if not n.startswith("_")]
