"""Kernel-granularity registry — the single source of truth.

The paper's central mechanism is that logical decode positions are realized
as *quantized physical work units*: query tiles in attention backends
(kBlockM / CTA_TILE_Q) and expert-token blocks in fused MoE kernels
(BLOCK_SIZE_M).  On TPU the corresponding quantum is the Pallas
``BlockSpec`` block shape chosen by our own kernels.

Every function here is used BOTH by the Pallas kernels in
``repro.kernels.*`` (to pick their grids) and by the NFP predictor in
``repro.core.nfp`` (to predict the boundary) — so predictor and
implementation can never drift apart.  This mirrors the paper's
methodology of reading M_attn / M_moe out of backend source (App. E.3,
F.3) except that here the "backend source" is this module.
"""
from __future__ import annotations

from dataclasses import dataclass


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# Attention query-tile selection (paper App. F; Tables 14-16).
#
# Two policies, mirroring the two GPU backends the paper inspects:
#   - "fixed64"  (FlashAttention-2-like): one branch, q_block = 64.
#     TPU rationale: 64 query rows x 128 lanes fills 4 bf16 VREG sublane
#     groups and keeps the MXU M-dim at 64 (half-systolic, fine for the
#     memory-bound decode regime).
#   - "adaptive" (FlashInfer-like): scheduler picks the tile from the packed
#     query workload -> 16 / 64 / 128 branches.  The branch boundaries are
#     the tau-analogues for attention.
# ---------------------------------------------------------------------------

ATTN_POLICY_FIXED = "fixed64"
ATTN_POLICY_ADAPTIVE = "adaptive"


def select_q_block(n_q: int, head_dim: int = 128,
                   policy: str = ATTN_POLICY_FIXED) -> int:
    """Query-tile rows executed per grid step (the TPU kBlockM)."""
    if policy == ATTN_POLICY_FIXED:
        return 64
    # adaptive: FlashInfer-style (Table 16), sublane-aligned for bf16
    if n_q <= 16:
        return 16
    if n_q <= 64 or head_dim >= 256:
        return 64
    return 128


def attn_padded_q(n_q: int, head_dim: int = 128,
                  policy: str = ATTN_POLICY_FIXED) -> int:
    """Physical query rows executed for n_q logical rows (Eq. 34)."""
    blk = select_q_block(n_q, head_dim, policy)
    return round_up(n_q, blk)


def m_attn(head_dim: int = 128, policy: str = ATTN_POLICY_FIXED) -> int:
    """M_attn: positions absorbable within one baseline query tile (Eq. 35).

    The baseline decode forward (N=1) launches one tile of
    ``select_q_block(1)`` rows; everything inside it is near-free.
    """
    return select_q_block(1, head_dim, policy)


# ---------------------------------------------------------------------------
# MoE expert-token block alignment (paper App. E; Tables 8-9).
#
# Our Pallas grouped-GEMM MoE kernel sorts tokens by expert and pads each
# expert's token count up to ``token_block`` rows (the BLOCK_SIZE_M
# analogue).  The selection rule mirrors the small/large-M branches of the
# GPU backends so the branch-validity bound tau exists structurally:
#     padded token dim M <= E  -> 16     (decode regime)
#     otherwise                -> 64     (prefill/training regime)
# ---------------------------------------------------------------------------


def select_token_block(m_tokens: int, n_experts: int,
                       quant: str = "bf16") -> int:
    """Expert-token row-block (BLOCK_SIZE_M analogue) for a fused MoE call.

    Branch rules mirror paper Table 9 (SGLang fused-MoE fallback):
      bf16/fp16:        M <= E -> 16, else 64
      per-tensor int8/fp8: M <= E -> 64, else 128
      block-wise fp8:   64 for any M
    """
    if quant in ("fp8_block", "int8_block"):
        return 64
    if quant in ("fp8", "int8"):
        return 64 if m_tokens <= n_experts else 128
    if m_tokens <= n_experts:
        return 16
    return 64


def moe_tau(n_experts: int) -> int:
    """Validity bound of the small-token branch (tau = E, paper Sec. 4.2)."""
    return n_experts


def m_moe(n_experts: int, quant: str = "bf16") -> int:
    """M_moe: expert-token padding granularity in the decode regime."""
    return select_token_block(1, n_experts, quant)


def moe_padded_tokens(tokens_per_expert, token_block: int) -> int:
    """Total physical expert-token rows executed (Eq. 28 summed)."""
    return int(sum(round_up(int(t), token_block) if t > 0 else 0
                   for t in tokens_per_expert))


# ---------------------------------------------------------------------------
# SSM scan-chunk granularity (our TPU extension; DESIGN.md §6).
# The Pallas chunked selective scan processes positions in chunks.
# ---------------------------------------------------------------------------

SSM_CHUNK = 16


def select_scan_chunk(n_positions: int) -> int:
    return SSM_CHUNK


def m_ssm() -> int:
    return SSM_CHUNK


def ssm_padded_positions(n: int) -> int:
    return round_up(n, SSM_CHUNK)


# ---------------------------------------------------------------------------
# MXU alignment — the secondary TPU-specific granularity (DESIGN.md §2):
# matmul M/N/K dims are executed in multiples of the 128x128 systolic tile;
# the LHS row dim additionally in sublane multiples (8 f32 / 16 bf16).
# ---------------------------------------------------------------------------


def mxu_padded_rows(m: int, dtype_bytes: int = 2) -> int:
    sublane = 8 * (4 // dtype_bytes)
    return round_up(m, sublane)


# ---------------------------------------------------------------------------
# Paged-KV block granularity.  With a paged cache the kv sequence is
# read (and, on the Pallas path, tiled) in fixed-size blocks, so the
# attended cache length is quantized up to the page boundary — a second
# attention-side granularity next to the query tile, entering the NFP
# idle term through ``core.nfp.n_idle_attn_general(kv_page=...)``.
# ---------------------------------------------------------------------------


def kv_padded_len(ell: int, kv_page: int) -> int:
    """Cache positions physically touched for ``ell`` logical positions
    under a ``kv_page``-sized paged cache (0 = dense, no quantization)."""
    if kv_page <= 0:
        return ell
    return round_up(max(ell, 1), kv_page)


@dataclass(frozen=True)
class GranularitySpec:
    """Bundle of granularity parameters for one backend configuration.

    ``kv_page`` is the paged-KV block size in positions (0 when the
    dense cache is in use) — the paging granularity knob the NFP
    attention idle term accounts for.
    """

    m_attn: int
    m_moe: int
    tau: int
    m_ssm: int
    attn_policy: str = ATTN_POLICY_FIXED
    kv_page: int = 0

    @classmethod
    def for_backend(cls, n_experts: int = 0,
                    attn_policy: str = ATTN_POLICY_FIXED,
                    head_dim: int = 128,
                    quant: str = "bf16",
                    kv_page: int = 0) -> "GranularitySpec":
        return cls(
            m_attn=m_attn(head_dim, attn_policy),
            m_moe=m_moe(max(n_experts, 1), quant),
            tau=moe_tau(n_experts) if n_experts else 0,
            m_ssm=m_ssm(),
            attn_policy=attn_policy,
            kv_page=kv_page,
        )
