"""Near-Free Parallelism: idle-compute baselines and the NFP principle.

Implements the paper's equations verbatim:

  Eq. 5   AI(N) = C(N)/B(N),  rho = phi/beta
  Eq. 8/9    Dense FFN:  AI = 2bN/s          -> N_idle = rho*s/(2b)
  Eq. 18/19  MoE FFN  (eta = 2 combine accesses)
  Eq. 21/22  Attention (KV-cache dominated)
  Eq. 12     dense model principle:   min(rho*s/2b, M_attn)
  Eq. 13     MoE balanced principle:  min(M_moe*E/k, tau, M_attn)
  Eq. 14     MoE skewed principle:    min(M_moe, M_attn)

plus the TPU-framework extensions documented in DESIGN.md §6:
  - generalized attention term for GQA / MLA / SWA geometries,
  - an SSM idle-compute term (same weight-stationary 1/b scaling as the
    dense FFN) with scan-chunk granularity,
  - model-level composition over an ArchConfig (first-exiting-module min).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.arch import (LAYER_ATTN, LAYER_HYBRID, LAYER_SSM, ArchConfig,
                             AttentionSpec)
from repro.core.granularity import GranularitySpec, kv_padded_len
from repro.core.hardware import BYTES_BF16, HardwareSpec

ETA_COMBINE = 2  # paper footnote 2: per-expert activation accesses in combine

INF = float("inf")


# ===========================================================================
# Arithmetic intensities (Eq. 8, 18, 21)
# ===========================================================================

def ai_dense(n: int, b: int, s: int = BYTES_BF16) -> float:
    """Eq. 8: AI_dense(N) = 2bN/s (weight-traffic-dominated)."""
    return 2.0 * b * n / s


def ai_moe(n: int, b: int, k: int, e_act: int, d_ff: int,
           s: int = BYTES_BF16, eta: int = ETA_COMBINE) -> float:
    """Eq. 18."""
    num = 4.0 * b * n * k * d_ff
    den = s * (2.0 * e_act * d_ff + b * n * (1 + 3 * k + eta * k))
    return num / den


def ai_attn(n: int, ell: int, s: int = BYTES_BF16) -> float:
    """Eq. 21 (MHA form; batch cancels)."""
    return 2.0 * n * ell / ((ell + n) * s)


# ===========================================================================
# Idle-compute boundaries (Eq. 9, 19, 22)
# ===========================================================================

def n_idle_dense(rho: float, b: int, s: int = BYTES_BF16) -> float:
    """Eq. 9: N_idle^dense ~= rho*s / (2b)."""
    return rho * s / (2.0 * b)


def n_idle_moe(rho: float, b: int, k: int, e_act: int, d_ff: int,
               s: int = BYTES_BF16, eta: int = ETA_COMBINE) -> float:
    """Eq. 19; +inf when execution stays memory-bound (4k*d_ff <= rho*s*(...))."""
    gate = 4.0 * k * d_ff - rho * s * (1 + 3 * k + eta * k)
    if gate <= 0:
        return INF
    return 2.0 * rho * s * e_act * d_ff / (b * gate)


def n_idle_attn(rho: float, ell: int, s: int = BYTES_BF16) -> float:
    """Eq. 22; +inf when 2L <= rho*s (memory-bound for all N)."""
    if 2.0 * ell <= rho * s:
        return INF
    return rho * s * ell / (2.0 * ell - rho * s)


def n_idle_attn_general(rho: float, ell: int, attn: AttentionSpec,
                        s: int = BYTES_BF16, kv_page: int = 0) -> float:
    """Generalized Eq. 22 for GQA / MLA / SWA geometries.

    C(N)   = 2*b*N*L_eff*h*(d_qk + d_v)      (scores + AV)
    B(N)   = b*(L_eff+N)*kv_bytes_per_token  (KV-cache traffic)
    solve AI(N) = rho for N.  Reduces exactly to Eq. 22 for MHA.

    ``kv_page`` > 0 models a PAGED cache: the committed cache is read
    (and tiled) in whole blocks, so the effective attended length is
    L_eff rounded up to the page boundary — both the per-position FLOPs
    and the KV bytes grow with the padded length, which shifts the idle
    boundary DOWN slightly (toward the rho*kv_b/(2*h*(d_qk+d_v))
    asymptote).  This is the paging-induced boundary shift
    ``predict_model`` reports when the engine serves a paged cache.
    """
    if attn.kind == "swa" and attn.window is not None:
        ell = min(ell, attn.window)
    ell = kv_padded_len(ell, kv_page)
    d_qk, d_v = attn.score_dims
    c_per = 2.0 * ell * attn.n_heads * (d_qk + d_v)         # FLOPs / position
    kv_b = float(attn.kv_cache_bytes_per_token)
    gate = c_per - rho * kv_b
    if gate <= 0:
        return INF
    return rho * ell * kv_b / gate


def n_idle_ssm(rho: float, b: int, s: int = BYTES_BF16) -> float:
    """SSM blocks are weight-stationary GEMM-dominated like dense FFNs:
    projections give AI = 2bN/s; the recurrence adds compute without weight
    traffic, so rho*s/(2b) is a (slightly conservative) idle bound."""
    return n_idle_dense(rho, b, s)


# ===========================================================================
# The NFP principle (Eq. 12-14) + model-level composition
# ===========================================================================

@dataclass(frozen=True)
class NFPPrediction:
    n_max: float
    limiting: str                 # which term is the min
    terms: Dict[str, float]       # every module-level term
    n_idle: float                 # pure idle-compute prediction (baseline)

    @property
    def overprediction(self) -> float:
        """How much the idle-compute intuition over-predicts (Table 24)."""
        if not math.isfinite(self.n_idle):
            return INF
        return self.n_idle / self.n_max if self.n_max > 0 else INF


def predict_dense(hw: HardwareSpec, gran: GranularitySpec, b: int,
                  s: int = BYTES_BF16) -> NFPPrediction:
    """Eq. 12: N_max^dense ~= min(rho*s/2b, M_attn)."""
    terms = {
        "dense_ffn_idle": n_idle_dense(hw.rho, b, s),
        "attn_tile": float(gran.m_attn),
    }
    lim = min(terms, key=terms.get)
    return NFPPrediction(terms[lim], lim, terms, terms["dense_ffn_idle"])


def predict_moe_balanced(hw: HardwareSpec, gran: GranularitySpec,
                         n_experts: int, k: int, d_ff: int, b: int = 1,
                         s: int = BYTES_BF16) -> NFPPrediction:
    """Eq. 13: N_max^{moe,bal} ~= min(M_moe*E/k, tau, M_attn)."""
    terms = {
        "moe_padding_capacity": gran.m_moe * n_experts / k,
        "tau_branch": float(gran.tau if gran.tau else n_experts),
        "attn_tile": float(gran.m_attn),
    }
    lim = min(terms, key=terms.get)
    idle = n_idle_moe(hw.rho, b, k, e_act=n_experts, d_ff=d_ff, s=s)
    return NFPPrediction(terms[lim], lim, terms, idle)


def predict_moe_skewed(hw: HardwareSpec, gran: GranularitySpec,
                       k: int, d_ff: int, b: int = 1,
                       s: int = BYTES_BF16) -> NFPPrediction:
    """Eq. 14: N_max^{moe,skew} ~= min(M_moe, M_attn)."""
    terms = {
        "moe_padding_local": float(gran.m_moe),
        "attn_tile": float(gran.m_attn),
    }
    lim = min(terms, key=terms.get)
    idle = n_idle_moe(hw.rho, b, k, e_act=k, d_ff=d_ff, s=s)
    return NFPPrediction(terms[lim], lim, terms, idle)


def predict_model(cfg: ArchConfig, hw: HardwareSpec, gran: GranularitySpec,
                  b: int, ell: int, routing: str = "balanced",
                  s: int = BYTES_BF16) -> NFPPrediction:
    """Model-level NFP: first-exiting-module min over the modules the
    architecture actually contains (paper Sec. 4 + DESIGN.md §6).

    - dense FFN present  -> rho*s/2b idle term
    - MoE FFN present    -> padding capacity (balanced) or M_moe (skewed),
                            tau branch bound, and its own idle term
    - attention present  -> M_attn tile term and generalized idle term
    - SSM present        -> rho*s/2b idle term and scan-chunk term
    The lm-head GEMM behaves like a dense FFN (weight-stationary) and is
    absorbed into the dense idle term.
    """
    pat = cfg.pattern()
    has_attn = any(p in (LAYER_ATTN, LAYER_HYBRID) for p in pat) and cfg.attention
    has_ssm = any(p in (LAYER_SSM, LAYER_HYBRID) for p in pat) and cfg.ssm
    terms: Dict[str, float] = {}
    idle_terms: Dict[str, float] = {}

    if cfg.ffn.kind == "dense":
        terms["dense_ffn_idle"] = n_idle_dense(hw.rho, b, s)
        idle_terms["dense_ffn"] = terms["dense_ffn_idle"]
    elif cfg.ffn.kind == "moe":
        e, k = cfg.ffn.n_experts, cfg.ffn.top_k
        if routing == "balanced":
            terms["moe_padding_capacity"] = gran.m_moe * e / k
            terms["tau_branch"] = float(gran.tau if gran.tau else e)
            e_act = e
        else:
            terms["moe_padding_local"] = float(gran.m_moe)
            e_act = k
        idle_terms["moe_ffn"] = n_idle_moe(hw.rho, b, k, e_act, cfg.ffn.d_ff, s)

    if has_attn:
        terms["attn_tile"] = float(gran.m_attn)
        idle_terms["attn"] = n_idle_attn_general(hw.rho, ell, cfg.attention, s,
                                                 kv_page=gran.kv_page)

    if has_ssm:
        terms["ssm_idle"] = n_idle_ssm(hw.rho, b, s)
        terms["ssm_chunk_capacity"] = float(gran.m_ssm)
        idle_terms["ssm"] = terms["ssm_idle"]

    # the idle-compute-only baseline = min over idle terms (no granularity)
    n_idle = min(idle_terms.values()) if idle_terms else INF
    lim = min(terms, key=terms.get)
    return NFPPrediction(terms[lim], lim, terms, n_idle)


# ===========================================================================
# Deployment budget (paper Sec. 6 / Table 24)
# ===========================================================================

def parallelism_budget(cfg: ArchConfig, hw: HardwareSpec,
                       gran: GranularitySpec, b: int, ell: int,
                       eps: float = 0.2,
                       routing: str = "balanced") -> int:
    """The near-free position budget an algorithm (speculative verification
    length, MTP length, diffusion block size) should not exceed.

    The fractional model boundary is FLOORED, never rounded: the budget
    is a promise that every position inside it is near-free, so a
    boundary of e.g. 34.4 must yield 34 — rounding up would schedule
    one position past the knee on every step.  ``int()`` happens to
    truncate positive floats the same way, but the budget contract is
    about flooring, so say it explicitly.
    """
    pred = predict_model(cfg, hw, gran, b, ell, routing=routing)
    n = pred.n_max
    return max(1, math.floor(n)) if math.isfinite(n) else cfg.max_seq_len
