"""NFP measurement protocol (paper App. C.1.2-C.1.3).

Works on any latency source: wall-clock timing of a callable (CPU sanity
sweeps), the roofline simulator (TPU-target curves), or recorded curves.
"""
from __future__ import annotations

import math
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Sequence, Tuple

try:                                    # hoisted: _block runs inside the
    import jax as _jax                  # timed loop, a per-call import
except Exception:                       # there is measurable overhead
    _jax = None

DEFAULT_EPS = 0.2


@dataclass
class LatencyCurve:
    ns: List[int]
    times: List[float]
    baseline_n: int = 1
    # optional per-sample relative spread (max-min over round medians,
    # normalized by the median) from ``time_callable`` — the noise floor
    # the autotune controller's variance gate reuses.  Empty for
    # simulated / recorded curves (deterministic sources).
    spreads: List[float] = field(default_factory=list)

    @property
    def baseline_time(self) -> float:
        if self.baseline_n not in self.ns:
            raise ValueError(
                f"baseline_n={self.baseline_n} was not sampled: the curve "
                f"covers N in {sorted(self.ns)}.  Add the baseline to the "
                "sweep (for balanced MoE use balanced_moe_baseline_n).")
        return self.times[self.ns.index(self.baseline_n)]

    @property
    def max_spread(self) -> float:
        """Largest relative per-round spread across the sweep — a single
        scalar noise floor for tolerance gating (0 when unknown)."""
        return max(self.spreads, default=0.0)

    def as_dict(self) -> Dict[int, float]:
        return dict(zip(self.ns, self.times))


def extract_nmax(curve: LatencyCurve, eps: float = DEFAULT_EPS,
                 contiguous: bool = False) -> int:
    """Eq. 4 / Eq. 24: largest sampled N with T(N) <= (1+eps)*T(baseline).

    For the load-balanced MoE case the baseline is the smallest N that
    activates all experts (Eq. 26) — pass it via ``curve.baseline_n``.

    ``contiguous=True`` stops at the FIRST above-tolerance N past the
    baseline instead of taking the global max: on a noisy wall-clock
    curve a single rebound sample beyond the knee (a lucky fast round at
    large N) would otherwise inflate N_max past the real boundary.  The
    calibrator uses this mode; the default keeps the paper's protocol.
    """
    t0 = curve.baseline_time
    best = curve.baseline_n
    for n, t in sorted(zip(curve.ns, curve.times)):
        if n < curve.baseline_n:
            continue
        if t <= (1.0 + eps) * t0:
            best = max(best, n)
        elif contiguous:
            break
    return best


def balanced_moe_baseline_n(n_experts: int, b: int, k: int) -> int:
    """Eq. 26: N_bal0 = ceil(E / (b*k)) — smallest N activating all experts."""
    return math.ceil(n_experts / (b * k))


def sensitivity_sweep(curve: LatencyCurve,
                      eps_values: Sequence[float] = (0.05, 0.10, 0.15, 0.20, 0.30),
                      ) -> Dict[float, int]:
    """App. I tolerance sweep."""
    return {eps: extract_nmax(curve, eps) for eps in eps_values}


# ---------------------------------------------------------------------------
# Wall-clock timing (CPU sanity layer).  Scaled-down version of the paper's
# protocol: warmup then R rounds x I iterations, median of per-round medians.
# ---------------------------------------------------------------------------

def time_callable(fn: Callable[[], object], warmup: int = 3, rounds: int = 5,
                  iters: int = 10) -> Tuple[float, float]:
    """Returns ``(median, spread)``: the median of per-round medians and
    the relative per-round spread ``(max - min) / median`` — the
    measured noise floor.  The autotune controller's variance gate
    consumes the spread directly instead of re-deriving noise from live
    serving steps."""
    for _ in range(warmup):
        r = fn()
        _block(r)
    round_medians = []
    for _ in range(rounds):
        samples = []
        for _ in range(iters):
            t0 = time.perf_counter()
            r = fn()
            _block(r)
            samples.append(time.perf_counter() - t0)
        round_medians.append(statistics.median(samples))
    med = statistics.median(round_medians)
    spread = ((max(round_medians) - min(round_medians)) / med
              if med > 0 else 0.0)
    return med, spread


def _block(result) -> None:
    """block_until_ready for jax outputs; no-op otherwise."""
    if _jax is None:
        return
    try:
        _jax.block_until_ready(result)
    except Exception:
        pass


def sweep_callable(make_fn: Callable[[int], Callable[[], object]],
                   n_values: Sequence[int], baseline_n: int = 1,
                   warmup: int = 3, rounds: int = 5, iters: int = 10,
                   ) -> LatencyCurve:
    """Measure T(N) over a sweep.  ``make_fn(n)`` returns a zero-arg callable
    executing one decode forward with n positions (pre-compiled outside the
    timed region, matching App. C.1.3's pre-allocation discipline)."""
    ns, times, spreads = [], [], []
    for n in n_values:
        fn = make_fn(int(n))
        med, spread = time_callable(fn, warmup, rounds, iters)
        times.append(med)
        spreads.append(spread)
        ns.append(int(n))
    return LatencyCurve(ns, times, baseline_n, spreads)


def staircase_boundaries(ns: Sequence[int], values: Sequence[float],
                         rel_jump: float = 0.05) -> List[int]:
    """Detect discrete staircase steps in a metric (runtime FLOPs / AI):
    the paper's RQ3 signature of granularity-governed execution."""
    steps = []
    for i in range(1, len(ns)):
        if values[i - 1] > 0 and (values[i] - values[i - 1]) / values[i - 1] > rel_jump:
            steps.append(int(ns[i]))
    return steps
