"""Hardware specifications and the balance point rho = phi / beta.

TPU v5e is the deployment target (roofline constants fixed by the brief).
The paper's three GPUs are kept as presets so the reproduction can be
cross-checked against the paper's own numbers (Table 2 / Table 24).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    phi: float           # peak bf16/fp16 compute, FLOP/s
    beta: float          # peak HBM bandwidth, bytes/s
    ici: float = 0.0     # per-link interconnect bandwidth, bytes/s
    n_ici_links: int = 0
    hbm_bytes: float = 0.0
    vmem_bytes: float = 0.0
    mxu_dim: int = 128   # systolic array side (TPU); tensor-core tile (GPU)

    @property
    def rho(self) -> float:
        """Hardware balance point (FLOP per byte)."""
        return self.phi / self.beta


# --- deployment target (constants fixed by the brief) ---------------------
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    phi=197e12,          # bf16 TFLOP/s per chip
    beta=819e9,          # HBM GB/s
    ici=50e9,            # ~GB/s per ICI link
    n_ici_links=4,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
    mxu_dim=128,
)

# --- paper's GPUs (Table 2) — used to validate the reproduction -----------
H20 = HardwareSpec("h20", phi=148e12, beta=4.0e12)
A800 = HardwareSpec("a800", phi=312e12, beta=2.039e12)
H800 = HardwareSpec("h800", phi=989e12, beta=3.35e12)

PRESETS = {h.name: h for h in (TPU_V5E, H20, A800, H800)}

BYTES_BF16 = 2
BYTES_F32 = 4


def get_hardware(name: str) -> HardwareSpec:
    return PRESETS[name]
