"""Architecture specification schema.

A single declarative schema covers all 10 assigned architectures (dense,
MoE, MLA, SWA, hybrid SSM+attention, pure SSM, encoder-decoder audio, VLM
backbone).  The NFP analytical model (``core.nfp`` / ``core.simulate``),
the model zoo (``repro.models``), the sharding rules (``repro.dist``) and
the dry-run launcher all consume this one schema, so an architecture is
defined exactly once in ``repro/configs/<id>.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttentionSpec:
    """Attention module description.

    kind:
      - "gqa":  grouped-query attention (covers MHA when n_kv == n_heads,
                MQA when n_kv == 1).
      - "mla":  multi-head latent attention (MiniCPM3 / DeepSeek style):
                KV cache stores a compressed latent per token.
      - "swa":  sliding-window GQA (Mixtral): effective cache length is
                min(L, window).
    """

    kind: str = "gqa"                    # gqa | mla | swa
    n_heads: int = 32
    n_kv_heads: int = 32
    head_dim: int = 128
    window: Optional[int] = None         # swa only
    # MLA-only geometry (MiniCPM3-4B defaults).
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64

    @property
    def q_dim(self) -> int:
        if self.kind == "mla":
            return self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_cache_bytes_per_token(self) -> int:
        """bf16 KV-cache bytes appended per token (the B(N) traffic unit)."""
        s = 2
        if self.kind == "mla":
            # latent + decoupled rope key, shared across heads
            return (self.kv_lora_rank + self.qk_rope_head_dim) * s
        return 2 * self.n_kv_heads * self.head_dim * s

    @property
    def score_dims(self) -> Tuple[int, int]:
        """(per-head qk dim, per-head v dim) used in score/AV matmuls."""
        if self.kind == "mla":
            return (self.qk_nope_head_dim + self.qk_rope_head_dim, self.v_head_dim)
        return (self.head_dim, self.head_dim)


@dataclass(frozen=True)
class FFNSpec:
    kind: str = "dense"                  # dense | moe | none
    d_ff: int = 0                        # dense intermediate (or expert d_ff for moe)
    activation: str = "swiglu"           # swiglu | gelu
    n_experts: int = 0                   # moe only
    top_k: int = 0                       # moe only
    n_shared_experts: int = 0            # moe: always-on shared experts


@dataclass(frozen=True)
class SSMSpec:
    kind: str = "mamba1"                 # mamba1 | mamba2
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64                   # mamba2 only
    n_groups: int = 1                    # mamba2 only

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclass(frozen=True)
class EncoderSpec:
    """Stub-frontend encoder (whisper / CLIP): the frontend itself is a stub;
    ``input_specs`` provides precomputed frame/patch embeddings."""

    n_layers: int = 4
    n_frames: int = 1500                 # encoder sequence length (stub output)
    frontend: str = "audio"              # audio | vision


# Layer kinds used in ``layer_pattern``.
LAYER_ATTN = "attn"                      # attention + ffn block
LAYER_SSM = "ssm"                        # pure SSM block
LAYER_HYBRID = "hybrid"                  # SSM block + (shared) attention block


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                          # moe|dense|hybrid|audio|vlm|ssm
    n_layers: int
    d_model: int
    vocab_size: int
    attention: Optional[AttentionSpec] = None
    ffn: FFNSpec = field(default_factory=FFNSpec)
    ssm: Optional[SSMSpec] = None
    encoder: Optional[EncoderSpec] = None
    layer_pattern: Optional[Tuple[str, ...]] = None  # defaults to all-attn
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    max_seq_len: int = 131072
    # hybrid (zamba2): one shared attention param set reused at every
    # LAYER_HYBRID position.
    shared_attention: bool = False
    notes: str = ""

    # ------------------------------------------------------------------
    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern is not None:
            if len(self.layer_pattern) != self.n_layers:
                raise ValueError(
                    f"layer_pattern has {len(self.layer_pattern)} entries "
                    f"for n_layers={self.n_layers}")
            return self.layer_pattern
        return tuple([LAYER_ATTN] * self.n_layers)

    def count_layers(self, kind: str) -> int:
        return sum(1 for p in self.pattern() if p == kind)

    @property
    def is_attention_free(self) -> bool:
        return all(p == LAYER_SSM for p in self.pattern())

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode working set: SSM / hybrid / sliding-window."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.attention is not None and self.attention.kind == "swa":
            return True
        return False

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    # -- parameter counting (used for MODEL_FLOPS = 6 N D and roofline) ----
    def param_count(self, active_only: bool = False) -> int:
        d = self.d_model
        n = 0
        # embeddings (+ untied lm head)
        n += self.vocab_size * d
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for kind in self.pattern():
            if kind in (LAYER_ATTN, LAYER_HYBRID):
                n += self._attn_params()
                if kind == LAYER_ATTN:
                    n += self._ffn_params(active_only)
            if kind in (LAYER_SSM, LAYER_HYBRID):
                n += self._ssm_params()
        if self.encoder is not None:
            enc_attn = AttentionSpec(
                n_heads=self.attention.n_heads,
                n_kv_heads=self.attention.n_kv_heads,
                head_dim=self.attention.head_dim,
            )
            per = (
                self._attn_params_for(enc_attn)
                + self._ffn_params(active_only)
            )
            n += self.encoder.n_layers * per
            # decoder cross-attention
            n += self.count_layers(LAYER_ATTN) * self._attn_params()
        if self.shared_attention:
            # hybrid shared-attn params were counted once per hybrid layer;
            # correct to a single shared set (+ its ffn)
            h = self.count_layers(LAYER_HYBRID)
            if h > 1:
                n -= (h - 1) * self._attn_params()
        return n

    def _attn_params(self) -> int:
        return self._attn_params_for(self.attention)

    def _attn_params_for(self, a: AttentionSpec) -> int:
        d = self.d_model
        if a.kind == "mla":
            qk_h = a.qk_nope_head_dim + a.qk_rope_head_dim
            n = d * a.q_lora_rank + a.q_lora_rank * a.n_heads * qk_h      # q proj
            n += d * (a.kv_lora_rank + a.qk_rope_head_dim)                # kv down
            n += a.kv_lora_rank * a.n_heads * (a.qk_nope_head_dim + a.v_head_dim)
            n += a.n_heads * a.v_head_dim * d                             # out
            return n
        q = d * a.n_heads * a.head_dim
        kv = 2 * d * a.n_kv_heads * a.head_dim
        o = a.n_heads * a.head_dim * d
        return q + kv + o

    def _ffn_params(self, active_only: bool) -> int:
        d = self.d_model
        f = self.ffn
        if f.kind == "none":
            return 0
        mats = 3 if f.activation == "swiglu" else 2
        per_expert = mats * d * f.d_ff
        if f.kind == "dense":
            return per_expert
        n_exp = f.top_k if active_only else f.n_experts
        n = n_exp * per_expert + f.n_shared_experts * per_expert
        n += d * f.n_experts  # router
        return n

    def _ssm_params(self) -> int:
        d = self.d_model
        s = self.ssm
        di = s.d_inner(d)
        n = d * 2 * di                  # in_proj (x and z)
        n += di * s.d_conv              # conv
        if s.kind == "mamba1":
            dt_rank = max(1, d // 16)
            n += di * (dt_rank + 2 * s.d_state)   # x_proj -> (dt, B, C)
            n += dt_rank * di                      # dt_proj
            n += di * s.d_state                    # A
        else:  # mamba2
            n_heads = di // s.head_dim
            n += d * (2 * s.n_groups * s.d_state + n_heads)  # B, C, dt heads
            n += 2 * s.n_groups * s.d_state * s.d_conv        # B/C convs
            n += n_heads                                      # A (per head)
        n += di * d                     # out_proj
        return n

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell (assigned per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                            # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


LM_SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Skip rules from the assignment brief (recorded in DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "long_500k skipped: pure full-attention arch (sub-quadratic required)"
    return True, ""
