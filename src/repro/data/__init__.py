"""repro.data — token pipelines."""
from repro.data.pipeline import (BinaryShards, DataConfig, SyntheticLM,
                                 make_pipeline)

__all__ = ["DataConfig", "SyntheticLM", "BinaryShards", "make_pipeline"]
