"""Token data pipeline: synthetic LM stream + file-backed binary shards.

Synthetic stream: Zipf-distributed unigrams overlaid with deterministic
bigram structure (token t is followed by (t*7+3) % vocab with prob ~0.5)
so a capable model's loss decreases well below the unigram entropy — used
by the integration tests and the ~100M-param example run.

File-backed: flat uint16/uint32 binary shards, host-sharded by
(process_index, num_processes) for multi-host training.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None         # None -> synthetic
    dtype: str = "uint16"


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        cfg = self.cfg
        while True:
            b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
            toks = np.empty((b, s), np.int32)
            toks[:, 0] = self.rng.choice(v, size=b, p=self.unigram)
            for t in range(1, s):
                follow = (toks[:, t - 1] * 7 + 3) % v
                rand = self.rng.choice(v, size=b, p=self.unigram)
                use_bigram = self.rng.random(b) < 0.5
                toks[:, t] = np.where(use_bigram, follow, rand)
            yield {"tokens": toks}


class BinaryShards:
    """Reads <path>/shard_*.bin flat token files."""

    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 num_processes: int = 1):
        self.cfg = cfg
        files = sorted(f for f in os.listdir(cfg.path)
                       if f.endswith(".bin"))
        self.files = files[process_index::num_processes]
        if not self.files:
            raise FileNotFoundError(f"no shards for host {process_index}")
        self.rng = np.random.default_rng(cfg.seed + process_index)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        cfg = self.cfg
        need = cfg.global_batch * (cfg.seq_len + 1)
        while True:
            for fname in self.files:
                arr = np.memmap(os.path.join(cfg.path, fname),
                                dtype=cfg.dtype, mode="r")
                n_windows = len(arr) // need
                order = self.rng.permutation(n_windows)
                for w in order:
                    chunk = np.asarray(arr[w * need:(w + 1) * need],
                                       np.int32)
                    toks = chunk.reshape(cfg.global_batch, cfg.seq_len + 1)
                    yield {"tokens": toks[:, :-1].copy()}


def make_pipeline(cfg: DataConfig, process_index: int = 0,
                  num_processes: int = 1):
    if cfg.path is None:
        return iter(SyntheticLM(cfg))
    return iter(BinaryShards(cfg, process_index, num_processes))
