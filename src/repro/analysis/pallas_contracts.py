"""Checker 3: Pallas kernel launch contracts, via dry-run capture.

``pl.pallas_call`` is monkeypatched with a recorder and each kernel's
ops-level entry is invoked on tiny representative decode-regime inputs
under ``jax.disable_jit()`` — so every operand, grid, BlockSpec and
scalar-prefetch VALUE is concrete without compiling or running any
kernel.  The captured launches then get checked statically:

  PK001  operand arity != num_scalar_prefetch + len(in_specs)
  PK002  kernel fn positional-parameter count != prefetch + inputs +
         outputs + scratch (skipped for *args kernels)
  PK003  a BlockSpec index map raises or returns the wrong rank
  PK004  an index map returns an OUT-OF-BOUNDS block index somewhere on
         the launch grid (evaluated per grid point with the real
         prefetch values — this is how a bad clamp in the ragged
         tile-skip map or a corrupt block-table entry surfaces)
  PK005  a block shape does not divide its operand dimension (silent
         partial edge tiles)

The same captures feed the granularity-drift checker: the block shapes
kernels ACTUALLY launch with are compared against what
``core.granularity`` declares (see ``granularity_drift``).
"""
from __future__ import annotations

import inspect
import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding

CHECKER = "pallas-contract"

KERNEL_PATHS = {
    "decode_attention": "src/repro/kernels/decode_attention/kernel.py",
    "moe_ffn": "src/repro/kernels/moe_ffn/kernel.py",
    "mamba_scan": "src/repro/kernels/mamba_scan/kernel.py",
}


@dataclass
class CapturedLaunch:
    label: str                      # "decode_attention_ragged/n1", ...
    kernel_path: str                # repo-relative kernel source path
    kernel_name: str
    grid: Tuple[int, ...]
    num_scalar_prefetch: int
    in_specs: List[Any]             # pl.BlockSpec
    out_specs: List[Any]
    in_shapes: List[Tuple[int, ...]]
    out_shapes: List[Tuple[int, ...]]
    prefetch_values: List[Any]      # concrete numpy arrays
    kernel_params: Optional[int]    # positional count, None for *args
    scratch_count: int = 0
    line: int = 1


@dataclass
class CaptureTarget:
    label: str
    kernel_path: str
    run: Callable[[], None] = field(repr=False, default=None)


def _specs_list(specs) -> List[Any]:
    if specs is None:
        return []
    if isinstance(specs, (list, tuple)):
        return list(specs)
    return [specs]


def capture_launches(targets: Optional[Sequence[CaptureTarget]] = None
                     ) -> List[CapturedLaunch]:
    """Run the capture targets with ``pl.pallas_call`` replaced by a
    recorder; returns one CapturedLaunch per pallas_call invocation."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental import pallas as pl

    if targets is None:
        targets = default_targets()
    captured: List[CapturedLaunch] = []
    current: Dict[str, str] = {"label": "", "path": ""}
    real = pl.pallas_call

    def fake_pallas_call(kernel, out_shape=None, *, grid_spec=None,
                         grid=(), in_specs=None, out_specs=None,
                         scratch_shapes=(), **kw):
        if grid_spec is not None:
            grid_ = tuple(grid_spec.grid)
            in_specs_ = _specs_list(grid_spec.in_specs)
            out_specs_ = _specs_list(grid_spec.out_specs)
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0) or 0)
            scratch = _specs_list(grid_spec.scratch_shapes)
        else:
            grid_ = tuple(grid) if isinstance(grid, (list, tuple)) else (grid,)
            in_specs_ = _specs_list(in_specs)
            out_specs_ = _specs_list(out_specs)
            nsp = 0
            scratch = _specs_list(scratch_shapes)
        out_structs = (list(out_shape) if isinstance(out_shape, (list, tuple))
                       else [out_shape])

        fn = kernel
        while hasattr(fn, "func"):        # unwrap functools.partial chains
            fn = fn.func
        try:
            sig_params = [p for p in inspect.signature(kernel).parameters
                          .values()]
            if any(p.kind == p.VAR_POSITIONAL for p in sig_params):
                n_params: Optional[int] = None
            else:
                n_params = sum(p.kind in (p.POSITIONAL_ONLY,
                                          p.POSITIONAL_OR_KEYWORD)
                               for p in sig_params)
        except (TypeError, ValueError):
            n_params = None

        def runner(*operands):
            captured.append(CapturedLaunch(
                label=current["label"],
                kernel_path=current["path"],
                kernel_name=getattr(fn, "__name__", str(fn)),
                grid=grid_,
                num_scalar_prefetch=nsp,
                in_specs=in_specs_,
                out_specs=out_specs_,
                in_shapes=[tuple(np.shape(o)) for o in operands[nsp:]],
                out_shapes=[tuple(s.shape) for s in out_structs],
                prefetch_values=[np.asarray(o) for o in operands[:nsp]],
                kernel_params=n_params,
                scratch_count=len(scratch),
            ))
            outs = [jnp.zeros(s.shape, s.dtype) for s in out_structs]
            return outs if isinstance(out_shape, (list, tuple)) else outs[0]
        return runner

    pl.pallas_call = fake_pallas_call
    try:
        with jax.disable_jit():
            for t in targets:
                current["label"], current["path"] = t.label, t.kernel_path
                t.run()
    finally:
        pl.pallas_call = real
    return captured


# ---------------------------------------------------------------------------
# representative decode-regime examples — small enough to run eagerly on
# any host, shaped to exercise multi-tile grids and the ragged clamps
# ---------------------------------------------------------------------------

def default_targets() -> List[CaptureTarget]:
    import jax.numpy as jnp

    kp = KERNEL_PATHS

    def ragged(n: int, window=None):
        def run():
            from repro.kernels.decode_attention import ops
            b, s, h, kv, dh = 2, 256, 4, 2, 128
            q = jnp.zeros((b, n, h, dh), jnp.float32)
            k = jnp.zeros((b, s, kv, dh), jnp.float32)
            v = jnp.zeros((b, s, kv, dh), jnp.float32)
            lens = jnp.asarray([0, 130], jnp.int32)   # row 1 spans 2 kv tiles
            ops.decode_attention_ragged(q, k, v, lens, window=window)
        return run

    def paged():
        from repro.kernels.decode_attention import ops
        n_phys, bs, kv, dh, b = 6, 16, 2, 128, 2
        q = jnp.zeros((b, 1, 4, dh), jnp.float32)
        kpool = jnp.zeros((n_phys, bs, kv, dh), jnp.float32)
        vpool = jnp.zeros((n_phys, bs, kv, dh), jnp.float32)
        lens = jnp.asarray([5, 30], jnp.int32)
        tables = jnp.asarray([[0, 1, 5, 5], [2, 3, 4, 5]], jnp.int32)
        ops.decode_attention_paged(q, kpool, vpool, lens, tables)

    def moe():
        from repro.kernels.moe_ffn import ops
        e, d, f, m = 8, 64, 512, 2
        params = {
            "w_gate": jnp.zeros((e, d, f), jnp.float32),
            "w_up": jnp.zeros((e, d, f), jnp.float32),
            "w_down": jnp.zeros((e, f, d), jnp.float32),
        }
        x = jnp.zeros((m, d), jnp.float32)
        sizes = jnp.asarray([1, 1, 0, 0, 0, 0, 0, 0], jnp.int32)
        ops.grouped_ffn(x, params, sizes, "swiglu", n_tokens=1)

    def scan():
        from repro.kernels.mamba_scan import ops
        b, s, di, ds = 1, 5, 8, 4
        x = jnp.zeros((b, s, di), jnp.float32)
        dt = jnp.zeros((b, s, di), jnp.float32)
        bi = jnp.zeros((b, s, ds), jnp.float32)
        ci = jnp.zeros((b, s, ds), jnp.float32)
        a = jnp.zeros((di, ds), jnp.float32)
        h0 = jnp.zeros((b, di, ds), jnp.float32)
        ops.selective_scan(x, dt, bi, ci, a, h0)

    return [
        CaptureTarget("decode_attention_ragged/n1", kp["decode_attention"],
                      ragged(1)),
        CaptureTarget("decode_attention_ragged/n65", kp["decode_attention"],
                      ragged(65)),
        CaptureTarget("decode_attention_ragged/n1_window",
                      kp["decode_attention"], ragged(1, window=64)),
        CaptureTarget("decode_attention_paged/n1", kp["decode_attention"],
                      paged),
        CaptureTarget("grouped_ffn/decode", kp["moe_ffn"], moe),
        CaptureTarget("selective_scan/decode", kp["mamba_scan"], scan),
    ]


# ---------------------------------------------------------------------------
# static checks over captured launches
# ---------------------------------------------------------------------------

MAX_GRID_POINTS = 8192


def _grid_points(grid: Tuple[int, ...]):
    total = math.prod(grid) if grid else 0
    pts = itertools.product(*(range(g) for g in grid))
    return itertools.islice(pts, MAX_GRID_POINTS), total


def check_launch(launch: CapturedLaunch) -> List[Finding]:
    out: List[Finding] = []

    def emit(rule: str, message: str) -> None:
        out.append(Finding(CHECKER, rule, launch.kernel_path, launch.line,
                           f"{launch.kernel_name}[{launch.label}]", message,
                           snippet=f"grid={launch.grid}"))

    nsp = launch.num_scalar_prefetch
    n_in, n_out = len(launch.in_shapes), len(launch.out_shapes)
    if len(launch.in_specs) != n_in:
        emit("PK001",
             f"{n_in} array operands but {len(launch.in_specs)} in_specs "
             f"(num_scalar_prefetch={nsp}): prefetch/operand arity drift")
        return out
    if launch.kernel_params is not None:
        want = nsp + n_in + n_out + launch.scratch_count
        if launch.kernel_params != want:
            emit("PK002",
                 f"kernel takes {launch.kernel_params} positional refs but "
                 f"the launch supplies {want} ({nsp} prefetch + {n_in} in "
                 f"+ {n_out} out + {launch.scratch_count} scratch)")

    pairs = (list(zip(launch.in_specs, launch.in_shapes))
             + list(zip(launch.out_specs, launch.out_shapes)))
    roles = ([f"in_specs[{i}]" for i in range(n_in)]
             + [f"out_specs[{i}]" for i in range(n_out)])
    points, total = None, 0
    for role, (spec, shape) in zip(roles, pairs):
        block = tuple(int(b) for b in (spec.block_shape or shape))
        if len(block) != len(shape):
            emit("PK003", f"{role}: block rank {len(block)} != operand "
                          f"rank {len(shape)} for shape {shape}")
            continue
        for d, (dim, blk) in enumerate(zip(shape, block)):
            if blk <= 0 or dim % blk:
                emit("PK005",
                     f"{role}: block {block} does not divide operand "
                     f"shape {shape} (dim {d}: {dim} % {blk} != 0) — "
                     "silent partial edge tile")
        index_map = spec.index_map
        if index_map is None:
            continue
        bounds = [max(1, -(-dim // blk)) for dim, blk in zip(shape, block)
                  if blk > 0] if all(b > 0 for b in block) else None
        if bounds is None:
            continue
        points, total = _grid_points(launch.grid)
        checked = 0
        for pt in points:
            try:
                idx = index_map(*pt, *launch.prefetch_values)
            except Exception as exc:  # wrong arity, bad prefetch indexing
                emit("PK003",
                     f"{role}: index map raised {type(exc).__name__} at "
                     f"grid point {pt}: {exc}")
                break
            if not isinstance(idx, (tuple, list)):
                idx = (idx,)
            if len(idx) != len(shape):
                emit("PK003",
                     f"{role}: index map returned {len(idx)} indices for "
                     f"rank-{len(shape)} operand at grid point {pt}")
                break
            bad = None
            for d, v in enumerate(idx):
                try:
                    vi = int(v)
                except Exception:
                    emit("PK003",
                         f"{role}: index map returned non-integer "
                         f"component {d} at grid point {pt}")
                    bad = "type"
                    break
                if not (0 <= vi < bounds[d]):
                    emit("PK004",
                         f"{role}: block index {vi} out of bounds "
                         f"[0, {bounds[d]}) in dim {d} at grid point "
                         f"{pt} (operand {shape}, block {block}) — "
                         "the DMA would read past the buffer")
                    bad = "oob"
                    break
            if bad:
                break
            checked += 1
        if total > MAX_GRID_POINTS and checked == MAX_GRID_POINTS:
            # sampled; note it rather than silently under-covering
            emit("PK003",
                 f"{role}: grid has {total} points, only first "
                 f"{MAX_GRID_POINTS} evaluated — shrink the capture "
                 "example")
    return out


def check(project=None, roots=None,
          captures: Optional[List[CapturedLaunch]] = None) -> List[Finding]:
    del project, roots
    if captures is None:
        captures = capture_launches()
    out: List[Finding] = []
    for launch in captures:
        out.extend(check_launch(launch))
    return out
