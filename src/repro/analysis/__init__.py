"""Hot-path static analysis for the serving stack.

Four checkers, one CLI (``python -m repro.analysis``):

  host-sync          blocking device->host transfers reachable from the
                     serving loop's decode hot path
  recompile-hazard   jit call sites fed shape-derived Python scalars,
                     jits constructed per call, dynamic shapes that
                     bypass the power-of-two prefill bucketing
  pallas-contract    BlockSpec index maps statically evaluated over the
                     launch grid for in-bounds access, divisibility and
                     scalar-prefetch arity
  granularity-drift  tile sizes ``core.granularity`` declares (consumed
                     by the Eq. 12-14 predictor) vs the block shapes the
                     kernels actually launch with, pinned by a committed
                     contract

Findings diff against ``analysis-baseline.json`` so existing debt is
suppressed while NEW findings fail CI (``--check-baseline``).  See
``docs/analysis.md``.
"""
from repro.analysis.baseline import (diff_against_baseline, load_baseline,
                                     write_baseline)
from repro.analysis.callgraph import Project
from repro.analysis.cli import run_checkers
from repro.analysis.findings import Finding

__all__ = ["Finding", "Project", "run_checkers", "load_baseline",
           "write_baseline", "diff_against_baseline"]
