"""Finding records + inline suppression pragmas.

A finding's FINGERPRINT deliberately excludes the line number: baselines
must survive unrelated edits shifting code up or down, so identity is
(checker, rule, file, enclosing symbol, normalized source snippet).  Two
identical snippets in the same symbol collapse to one fingerprint; the
baseline stores a count so a second occurrence still surfaces as new.

Inline pragmas mark SANCTIONED syncs (e.g. the one (batch, width) i32
token transfer every serving loop fundamentally needs)::

    preds = np.asarray(greedy_tokens(logits))  # analysis: allow-host-sync

``allow-<checker>`` suppresses any rule of that checker on the lines the
flagged expression spans; ``allow-<rule>`` (e.g. ``allow-hs002``) only
that rule.  Pragma suppressions are invisible in default output (they
are design decisions, not debt) — ``--show-suppressed`` lists them.
"""
from __future__ import annotations

import ast
import hashlib
import re
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Set

PRAGMA_RE = re.compile(r"#\s*analysis:\s*allow-([a-z0-9_-]+)")


@dataclass(frozen=True)
class Finding:
    checker: str          # "host-sync" | "recompile-hazard" | ...
    rule: str             # "HS001", ...
    path: str             # repo-relative posix path
    line: int
    symbol: str           # enclosing function qualname (or module)
    message: str
    snippet: str = ""

    @property
    def fingerprint(self) -> str:
        basis = "|".join([self.checker, self.rule, self.path, self.symbol,
                          " ".join(self.snippet.split())])
        return hashlib.sha256(basis.encode()).hexdigest()[:16]

    def as_dict(self) -> Dict:
        d = asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    def render(self) -> str:
        head = f"{self.path}:{self.line}: [{self.checker}/{self.rule}]"
        src = f"\n      {self.snippet}" if self.snippet else ""
        return f"{head} {self.symbol}: {self.message}{src}"


def scan_pragmas(source: str) -> Dict[int, Set[str]]:
    """{1-based line: set of allow-tokens} for one file's source."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        toks = {m.lower() for m in PRAGMA_RE.findall(text)}
        if toks:
            out[i] = toks
    return out


def pragma_allows(pragmas: Dict[int, Set[str]], node: ast.AST,
                  checker: str, rule: str) -> bool:
    """True when an ``# analysis: allow-...`` pragma covers ``node``."""
    lo = getattr(node, "lineno", None)
    if lo is None:
        return False
    hi = getattr(node, "end_lineno", lo) or lo
    want = {checker.lower(), rule.lower()}
    for ln in range(lo, hi + 1):
        if pragmas.get(ln, set()) & want:
            return True
    return False


def snippet_of(source: str, node: ast.AST, limit: int = 160) -> str:
    seg: Optional[str] = None
    try:
        seg = ast.get_source_segment(source, node)
    except Exception:
        seg = None
    if not seg:
        return ""
    seg = " ".join(seg.split())
    return seg if len(seg) <= limit else seg[:limit - 3] + "..."


def sort_findings(findings: List[Finding]) -> List[Finding]:
    return sorted(findings,
                  key=lambda f: (f.checker, f.path, f.line, f.rule))
