"""Checker 4: granularity drift — tiles declared vs launched vs pinned.

The NFP predictor (Eqs. 12-14 in ``core.nfp``) reads its tile sizes
from ``core.granularity``; the Pallas kernels read the SAME selectors to
build their BlockSpecs.  That shared source prevents accidental skew —
but it also means a careless edit to a selector silently moves BOTH the
prediction and the kernels, corrupting every calibrated budget without
any test noticing.  So the baseline pins a third copy: the
``granularity_contract``, committed and code-reviewed.

Three-way comparison per tile knob:

  GD001  declared (what ``core.granularity`` computes today)
         != contract (what the committed baseline pins)
  GD002  launched (the block shape a capture-harness kernel launch
         actually used) != declared — a kernel hardcoding or override
         has drifted off the registry
  GD003  knob missing from the contract (new tile never pinned)

Drift findings are NEVER baseline-suppressible: the only way to clear
them is to update the pinned contract (``--write-baseline``), which
shows up in review as an explicit granularity change.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.analysis.findings import Finding
from repro.analysis.pallas_contracts import CapturedLaunch, capture_launches

CHECKER = "granularity-drift"

GRANULARITY_PATH = "src/repro/core/granularity.py"

# tile knob -> (capture label, which spec, which block-shape axis)
_CAPTURE_SOURCES = {
    "m_attn_decode": ("decode_attention_ragged/n1", 0, -2),
    "k_block": ("decode_attention_ragged/n1", 1, -2),
    "m_moe_decode": ("grouped_ffn/decode", 0, 0),
    "m_ssm": ("selective_scan/decode", 0, 1),
}


def declared_tiles() -> Dict[str, int]:
    """Tile sizes ``core.granularity`` (and the attention ops constant)
    declare for the decode regime — the values Eqs. 12-14 consume via
    ``GranularitySpec.for_backend``."""
    from repro.core.granularity import (GranularitySpec, select_q_block,
                                        select_token_block, SSM_CHUNK)
    from repro.kernels.decode_attention.ops import K_BLOCK

    spec = GranularitySpec.for_backend(n_experts=8, head_dim=128)
    declared = {
        "m_attn_decode": int(select_q_block(1, 128)),
        "m_moe_decode": int(select_token_block(1, 8)),
        "m_ssm": int(SSM_CHUNK),
        "k_block": int(K_BLOCK),
    }
    # the predictor consumes the SAME numbers through GranularitySpec —
    # if for_backend diverges from the selectors, that is drift too
    if spec.m_attn != declared["m_attn_decode"]:
        declared["m_attn_decode"] = -abs(spec.m_attn)    # force mismatch
    if spec.m_moe != declared["m_moe_decode"]:
        declared["m_moe_decode"] = -abs(spec.m_moe)
    if spec.m_ssm != declared["m_ssm"]:
        declared["m_ssm"] = -abs(spec.m_ssm)
    return declared


def launched_tiles(captures: List[CapturedLaunch]) -> Dict[str, int]:
    """Block shapes the capture-harness launches actually used."""
    by_label = {c.label: c for c in captures}
    out: Dict[str, int] = {}
    for knob, (label, spec_i, axis) in _CAPTURE_SOURCES.items():
        launch = by_label.get(label)
        if launch is None or spec_i >= len(launch.in_specs):
            continue
        block = launch.in_specs[spec_i].block_shape
        if block:
            out[knob] = int(block[axis])
    return out


def check_drift(contract: Optional[Dict[str, int]],
                declared: Optional[Dict[str, int]] = None,
                launched: Optional[Dict[str, int]] = None,
                captures: Optional[List[CapturedLaunch]] = None
                ) -> List[Finding]:
    if declared is None:
        declared = declared_tiles()
    if launched is None:
        if captures is None:
            captures = capture_launches()
        launched = launched_tiles(captures)
    contract = contract or {}
    out: List[Finding] = []

    def emit(rule: str, knob: str, message: str) -> None:
        out.append(Finding(CHECKER, rule, GRANULARITY_PATH, 1, knob,
                           message))

    for knob in sorted(declared):
        dec = declared[knob]
        if knob not in contract:
            emit("GD003", knob,
                 f"tile knob {knob!r} (= {dec}) is not pinned in the "
                 "baseline's granularity_contract; regenerate with "
                 "--write-baseline to pin it")
        elif contract[knob] != dec:
            emit("GD001", knob,
                 f"core.granularity declares {knob}={dec} but the pinned "
                 f"contract says {contract[knob]}: the Eq. 12-14 "
                 "predictor inputs changed — if intentional, update the "
                 "contract via --write-baseline (and recalibrate)")
        lau = launched.get(knob)
        if lau is not None and lau != dec:
            emit("GD002", knob,
                 f"kernels launch with {knob}={lau} but core.granularity "
                 f"declares {dec}: kernel block shapes have drifted off "
                 "the registry the NFP predictor reads — the predicted "
                 "boundary no longer describes the kernels serving it")
    return out


def check(project=None, roots=None,
          captures: Optional[List[CapturedLaunch]] = None,
          contract: Optional[Dict[str, int]] = None) -> List[Finding]:
    del project, roots
    return check_drift(contract, captures=captures)
