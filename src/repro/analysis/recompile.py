"""Checker 2: recompile hazards around ``jax.jit``.

One compiled executable per (shapes, static args) is the contract the
serving stack's throughput rests on — a shape or static value that
varies per request silently turns every step into a fresh XLA compile.

  RH001  jax.jit (or functools.partial(jax.jit, ...)) CONSTRUCTED
         inside a function body: the jit cache is keyed on the wrapper
         object, so a per-call wrapper compiles every single call
  RH002  a call to a project-jitted function feeds a SHAPE-DERIVED
         Python scalar into a static_argnames parameter: one compile
         per distinct runtime shape
  RH003  an array built with shape-derived dimensions (np.zeros((b,
         len(x))), np.pad by a data-dependent amount, np.arange(n), ...)
         flows into a project-jitted call: a dynamic operand shape, one
         compile per distinct value

"Shape-derived" taint is STICKY (a branch that taints a name keeps it
tainted — the hazard exists if ANY path produces a varying shape) and is
cleansed only by the power-of-two bucketing helpers (functions whose
name contains "bucket"): bucketing is exactly the sanctioned way to turn
an unbounded shape family into a small compile set.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.callgraph import (FunctionInfo, Project, dotted_name)
from repro.analysis.findings import (Finding, pragma_allows, scan_pragmas,
                                     snippet_of)

CHECKER = "recompile-hazard"

_ARRAY_CTORS = {"zeros", "ones", "full", "empty", "arange"}
_PROPAGATING = {"concatenate", "pad", "stack", "repeat", "tile", "append",
                "asarray", "array", "broadcast_to", "reshape"}


def check(project: Project, roots=None) -> List[Finding]:
    """Scan EVERY project function (hazards outside the hot path still
    poison the compile cache the hot path shares)."""
    del roots
    out: List[Finding] = []
    for qual in sorted(project.functions):
        out.extend(_check_function(project, project.functions[qual]))
    return out


class _ShapeTaint:
    """Sticky shape-derived / dynamic-shape-array name sets."""

    def __init__(self, project: Project, fi: FunctionInfo):
        self.project = project
        self.fi = fi
        self.shape_vars: Set[str] = set()   # host scalars derived of shapes
        self.dyn_vars: Set[str] = set()     # arrays with derived dimensions

    def build(self) -> None:
        for _ in range(2):
            self._pass(self.fi.node.body)

    # -- classification ------------------------------------------------
    def _cleansed(self, call: ast.Call) -> bool:
        d = dotted_name(call.func) or ""
        return "bucket" in d.split(".")[-1]

    def shape_derived(self, expr: ast.AST) -> bool:
        if isinstance(expr, ast.Attribute):
            return expr.attr == "shape" or self.shape_derived(expr.value)
        if isinstance(expr, ast.Name):
            return expr.id in self.shape_vars
        if isinstance(expr, ast.Subscript):
            return self.shape_derived(expr.value)
        if isinstance(expr, ast.Call):
            if self._cleansed(expr):
                return False
            d = dotted_name(expr.func) or ""
            if d == "len" or d.endswith(".shape"):
                return True
            if self.project.canonical(self.fi, d) in (
                    "jax.numpy.shape", "numpy.shape"):
                return True
            # method calls on a tainted receiver stay tainted
            # (lens.items(), by_len.values(), ...)
            if (isinstance(expr.func, ast.Attribute)
                    and self.shape_derived(expr.func.value)):
                return True
            # calls propagate taint from their arguments (min/max/sum/
            # round_up of a shape-derived value is still shape-derived)
            return any(self.shape_derived(a) for a in expr.args)
        if isinstance(expr, ast.BinOp):
            return (self.shape_derived(expr.left)
                    or self.shape_derived(expr.right))
        if isinstance(expr, ast.UnaryOp):
            return self.shape_derived(expr.operand)
        if isinstance(expr, ast.IfExp):
            return (self.shape_derived(expr.body)
                    or self.shape_derived(expr.orelse))
        if isinstance(expr, ast.Slice):
            return any(e is not None and self.shape_derived(e)
                       for e in (expr.lower, expr.upper, expr.step))
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            return any(self.shape_derived(e) for e in expr.elts)
        if isinstance(expr, ast.Dict):
            return any(v is not None and self.shape_derived(v)
                       for v in expr.values)
        if isinstance(expr, (ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            if any(self._iter_tainted(g.iter) for g in expr.generators):
                return True
            val = expr.value if isinstance(expr, ast.DictComp) else expr.elt
            return self.shape_derived(val)
        return False

    def _iter_tainted(self, it: ast.AST) -> bool:
        return self.shape_derived(it) or self.dynamic_array(it)

    def dynamic_array(self, expr: ast.AST) -> bool:
        """Array-valued expression with a shape-derived dimension."""
        if isinstance(expr, ast.Name):
            return expr.id in self.dyn_vars
        if isinstance(expr, ast.Subscript):
            # x[:n] with a derived bound IS a dynamic slice
            if self.shape_derived(expr.slice):
                return True
            return self.dynamic_array(expr.value)
        if isinstance(expr, ast.Call):
            d = dotted_name(expr.func) or ""
            leaf = d.split(".")[-1]
            if leaf in _ARRAY_CTORS:
                if any(self.shape_derived(a) for a in expr.args):
                    return True
            if leaf in _PROPAGATING or leaf in _ARRAY_CTORS:
                if any(self.dynamic_array(a) or self.shape_derived(a)
                       for a in expr.args):
                    return True
            return False
        if isinstance(expr, ast.BinOp):
            return (self.dynamic_array(expr.left)
                    or self.dynamic_array(expr.right))
        if isinstance(expr, (ast.Tuple, ast.List)):
            return any(self.dynamic_array(e) for e in expr.elts)
        return False

    # -- sticky environment --------------------------------------------
    def _mark(self, target: ast.AST, shape: bool, dyn: bool) -> None:
        if isinstance(target, ast.Name):
            if shape:
                self.shape_vars.add(target.id)
            if dyn:
                self.dyn_vars.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._mark(e, shape, dyn)
        elif isinstance(target, ast.Starred):
            self._mark(target.value, shape, dyn)

    def _pass(self, stmts) -> None:
        for st in stmts:
            if isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                value = st.value
                if value is None:
                    continue
                targets = (st.targets if isinstance(st, ast.Assign)
                           else [st.target])
                shape = self.shape_derived(value)
                dyn = self.dynamic_array(value)
                for t in targets:
                    self._mark(t, shape, dyn)
            elif isinstance(st, ast.Expr) and isinstance(st.value, ast.Call):
                # container mutation: d.setdefault(shape_derived, ...) /
                # xs.append(dyn) taints the container — walk nested
                # method chains (by_len.setdefault(p, []).append(s))
                # down to the base Name, collecting every call's args
                node, args = st.value, []
                while isinstance(node, ast.Call):
                    args.extend(node.args)
                    node = node.func
                    if isinstance(node, ast.Attribute):
                        if node.attr not in ("append", "setdefault", "add",
                                             "insert", "extend", "update"):
                            break
                        node = node.value
                if isinstance(node, ast.Name):
                    if any(self.shape_derived(a) for a in args):
                        self.shape_vars.add(node.id)
                    if any(self.dynamic_array(a) for a in args):
                        self.dyn_vars.add(node.id)
            elif isinstance(st, ast.For):
                if self._iter_tainted(st.iter):
                    self._mark(st.target, True, False)
                self._pass(st.body + st.orelse)
            elif isinstance(st, (ast.While, ast.If)):
                self._pass(st.body + st.orelse)
            elif isinstance(st, ast.With):
                self._pass(st.body)
            elif isinstance(st, ast.Try):
                self._pass(st.body + st.orelse + st.finalbody)
                for h in st.handlers:
                    self._pass(h.body)
            elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._pass(st.body)


def _jit_constructor(call: ast.Call) -> bool:
    d = dotted_name(call.func)
    if d in ("jax.jit", "jit"):
        return True
    if d in ("functools.partial", "partial") and call.args:
        return dotted_name(call.args[0]) in ("jax.jit", "jit")
    return False


def _check_function(project: Project, fi: FunctionInfo) -> List[Finding]:
    info = project.modules[fi.module]
    pragmas = scan_pragmas(info.source)
    taint = _ShapeTaint(project, fi)
    taint.build()
    out: List[Finding] = []
    rel = fi.path.relative_to(project.rel_to).as_posix()

    def emit(node: ast.AST, rule: str, message: str) -> None:
        if pragma_allows(pragmas, node, CHECKER, rule):
            return
        out.append(Finding(CHECKER, rule, rel, node.lineno, fi.qualname,
                           message, snippet_of(info.source, node)))

    # walk the BODY only: the function's own decorators are where a
    # legitimate module-scope jit lives (a nested def's jit decorator,
    # reached through the body walk, IS a per-call construction)
    for node in (n for stmt in fi.node.body for n in ast.walk(stmt)):
        if not isinstance(node, ast.Call):
            continue
        if _jit_constructor(node):
            emit(node, "RH001",
                 "jax.jit constructed inside a function body: the "
                 "compile cache keys on the wrapper object, so every "
                 "call builds a fresh executable — hoist to module "
                 "scope or cache the wrapper")
            continue
        targets = project.resolve_call(fi, node)
        jitted = [project.functions[q] for q in targets
                  if project.functions[q].is_jitted]
        for callee in jitted:
            _check_jit_callsite(node, callee, taint, emit)
    return out


def _check_jit_callsite(call: ast.Call, callee: FunctionInfo,
                        taint: _ShapeTaint, emit) -> None:
    static = set(callee.static_argnames)

    def param_for(i: int) -> Optional[str]:
        return callee.params[i] if i < len(callee.params) else None

    for i, arg in enumerate(call.args):
        if isinstance(arg, ast.Starred):
            continue
        name = param_for(i)
        if name in static and taint.shape_derived(arg):
            emit(arg, "RH002",
                 f"static arg {name!r} of jitted {callee.name}() is "
                 "shape-derived: one compile per distinct runtime "
                 "shape — bucket it or make it a traced operand")
        elif name not in static and taint.dynamic_array(arg):
            emit(arg, "RH003",
                 f"operand {name or i!r} of jitted {callee.name}() has "
                 "shape-derived dimensions that bypass power-of-two "
                 "bucketing: one compile per distinct shape")
    for kw in call.keywords:
        if kw.arg is None:
            continue
        if kw.arg in static and taint.shape_derived(kw.value):
            emit(kw.value, "RH002",
                 f"static arg {kw.arg!r} of jitted {callee.name}() is "
                 "shape-derived: one compile per distinct runtime "
                 "shape — bucket it or make it a traced operand")
        elif kw.arg not in static and taint.dynamic_array(kw.value):
            emit(kw.value, "RH003",
                 f"operand {kw.arg!r} of jitted {callee.name}() has "
                 "shape-derived dimensions that bypass power-of-two "
                 "bucketing: one compile per distinct shape")
