"""Checker 1: blocking device->host syncs in the serving hot path.

Walks every project function reachable from the serving roots
(``ServingLoop.step`` / ``DecodeEngine.decode_slots`` by default) and
flags expressions that force the host to WAIT on the device:

  HS001  int()/float()/bool() on a device value — blocks until the
         scalar materializes (the classic per-step budget-read stall)
  HS002  np.asarray()/np.array() on a device value — synchronous full
         transfer of the operand
  HS003  .item()/.tolist() on a device value
  HS004  Python iteration (for / list / sorted / comprehension) over a
         device array — one sync PER ELEMENT
  HS005  jax.device_get / block_until_ready — unconditionally

Host->device uploads (``jnp.asarray(host)``) are NOT flagged: they are
cheap and asynchronous; the principle the serving loop follows is that
per-step control decisions read host mirrors, and device results cross
back once per step through sanctioned, pragma-marked transfers.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.callgraph import (DeviceTaint, FunctionInfo, Project,
                                      dotted_name)
from repro.analysis.findings import (Finding, pragma_allows, scan_pragmas,
                                     snippet_of)

CHECKER = "host-sync"

DEFAULT_ROOTS = (
    "repro.serving.scheduler.ServingLoop.step",
    "repro.serving.engine.DecodeEngine.decode_slots",
)

_SCALAR_CASTS = {"int", "float", "bool", "complex"}
_ITER_BUILTINS = {"list", "tuple", "sorted", "set", "sum", "max", "min",
                  "enumerate", "zip"}
_NUMPY_PULLS = {"numpy.asarray", "numpy.array", "numpy.copy",
                "numpy.ascontiguousarray"}


def check(project: Project, roots=DEFAULT_ROOTS) -> List[Finding]:
    findings: List[Finding] = []
    hot = project.reachable(roots)
    for qual in sorted(hot):
        fi = project.functions[qual]
        findings.extend(_check_function(project, fi))
    return findings


def _check_function(project: Project, fi: FunctionInfo) -> List[Finding]:
    info = project.modules[fi.module]
    pragmas = scan_pragmas(info.source)
    taint = DeviceTaint(project, fi)
    env = taint.build_env()
    out: List[Finding] = []
    seen: Set[int] = set()

    def emit(node: ast.AST, rule: str, message: str) -> None:
        if id(node) in seen or pragma_allows(pragmas, node, CHECKER, rule):
            return
        seen.add(id(node))
        rel = fi.path.relative_to(project.rel_to).as_posix()
        out.append(Finding(CHECKER, rule, rel, node.lineno, fi.qualname,
                           message, snippet_of(info.source, node)))

    def visit_expr(node: ast.AST) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                _check_call(sub)
            elif isinstance(sub, (ast.ListComp, ast.SetComp, ast.DictComp,
                                  ast.GeneratorExp)):
                for gen in sub.generators:
                    if taint.is_device(gen.iter, env):
                        emit(gen.iter, "HS004",
                             "comprehension iterates a device array "
                             "(one blocking transfer per element)")

    def _check_call(call: ast.Call) -> None:
        func = call.func
        d = dotted_name(func)
        full = project.canonical(fi, d) if d else ""
        if full in ("jax.device_get", "jax.block_until_ready"):
            emit(call, "HS005",
                 f"{d} is an unconditional blocking device->host sync")
            return
        if (isinstance(func, ast.Attribute)
                and func.attr == "block_until_ready"):
            emit(call, "HS005",
                 ".block_until_ready() blocks the host on device work")
            return
        if not call.args:
            return
        arg0 = call.args[0]
        if isinstance(func, ast.Name) and func.id in _SCALAR_CASTS:
            if taint.is_device(arg0, env):
                emit(call, "HS001",
                     f"{func.id}() on a device value blocks until the "
                     "device scalar materializes; keep a host mirror or "
                     "batch the readback")
        elif isinstance(func, ast.Name) and func.id in _ITER_BUILTINS:
            if taint.is_device(arg0, env):
                emit(call, "HS004",
                     f"{func.id}() over a device array forces a blocking "
                     "transfer; pull once with a sanctioned np.asarray "
                     "instead")
        elif full in _NUMPY_PULLS:
            if taint.is_device(arg0, env):
                emit(call, "HS002",
                     f"{d}() on a device value is a synchronous full "
                     "transfer; move the computation on-device and "
                     "transfer one small result per step")

    # statement-level sinks: for-loops over device arrays, .item()
    for node in ast.walk(fi.node):
        if isinstance(node, ast.For) and taint.is_device(node.iter, env):
            emit(node.iter, "HS004",
                 "for-loop iterates a device array (one blocking "
                 "transfer per element)")
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr in ("item", "tolist")
              and taint.is_device(node.func.value, env)):
            emit(node, "HS003",
                 f".{node.func.attr}() on a device value is a blocking "
                 "scalar readback")
    visit_expr(fi.node)
    return out
