"""AST project index, call-graph reachability, and device-value taint.

Everything downstream (host-sync, recompile-hazard) runs off ONE pass
over the source tree — no imports of the analyzed code, so the analyzer
can inspect trees that would not even import (test fixtures, broken
branches).

Resolution is deliberately an OVER-approximation: an attribute call
``x.step()`` resolves to EVERY project function named ``step`` (with a
same-class fast path for ``self.method()``).  For a hot-path linter the
cost of over-reach is a too-wide hot set, which the baseline absorbs;
the cost of under-reach would be silent misses.

Device taint answers "does this expression hold a device array?":

  sources   calls into ``jax.*`` / ``jnp.*`` (minus host-safe metadata
            accessors), calls to project functions that return device
            values (a fixpoint seeded with every jitted function),
            parameters annotated ``Array``/``jax.Array``, attributes
            assigned device values ANYWHERE in the project (attribute
            taint is name-global — ``self.cache`` is device no matter
            which class you read it from).
  not       ``.shape`` / ``.ndim`` / ``.dtype`` metadata, ``jnp.shape``,
            ``numpy.*`` results (an ``np.asarray(device)`` SYNC is the
            sink itself; its result lives on the host).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

# attribute reads that return host metadata, never a device array
HOST_META_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "sharding"}
# jax-namespace calls that return host values (shape tuples, ints, ...)
HOST_SAFE_CALLS = {
    "jax.numpy.shape", "jax.numpy.ndim", "jax.numpy.size",
    "jax.numpy.result_type", "jax.eval_shape", "jax.default_backend",
    "jax.local_device_count", "jax.device_count", "jax.tree.structure",
    "jax.tree_util.tree_structure",
}
# array-method calls whose RESULT is host-side (they are sync sinks,
# flagged separately by the host-sync checker)
HOST_RESULT_METHODS = {"item", "tolist"}
DEVICE_PARAM_ANNOTATIONS = {"Array", "jax.Array", "jnp.ndarray",
                            "jax.numpy.ndarray"}


def dotted_name(node: ast.AST) -> Optional[str]:
    """Flatten ``a.b.c`` attribute chains rooted at a Name; else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclass
class FunctionInfo:
    qualname: str                    # pkg.mod.Class.fn | pkg.mod.fn
    name: str
    module: str                      # pkg.mod
    cls: Optional[str]               # bare class name, if a method
    node: ast.AST                    # FunctionDef / AsyncFunctionDef
    path: Path
    is_jitted: bool = False
    static_argnames: Tuple[str, ...] = ()
    params: Tuple[str, ...] = ()     # positional params, "self" stripped
    calls: Set[str] = field(default_factory=set)   # resolved qualnames


@dataclass
class ModuleInfo:
    name: str
    path: Path
    tree: ast.Module
    source: str
    imports: Dict[str, str] = field(default_factory=dict)  # alias -> dotted


def _jit_info(deco: ast.AST) -> Optional[Tuple[Tuple[str, ...]]]:
    """(static_argnames,) when ``deco`` is jax.jit or
    functools.partial(jax.jit, static_argnames=...); else None."""
    d = dotted_name(deco)
    if d in ("jax.jit", "jit"):
        return ((),)
    if isinstance(deco, ast.Call):
        fn = dotted_name(deco.func)
        if fn in ("jax.jit", "jit"):
            return (_static_argnames(deco),)
        if fn in ("functools.partial", "partial") and deco.args:
            inner = dotted_name(deco.args[0])
            if inner in ("jax.jit", "jit"):
                return (_static_argnames(deco),)
    return None


def _static_argnames(call: ast.Call) -> Tuple[str, ...]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                return tuple(e.value for e in v.elts
                             if isinstance(e, ast.Constant))
    return ()


class Project:
    """Parsed index of every module under one source directory."""

    def __init__(self, src_dir: Path, rel_to: Optional[Path] = None):
        self.src_dir = Path(src_dir)
        self.rel_to = Path(rel_to) if rel_to else self.src_dir.parent
        self.modules: Dict[str, ModuleInfo] = {}
        self.functions: Dict[str, FunctionInfo] = {}
        self.by_name: Dict[str, List[str]] = {}
        self.classes: Dict[str, str] = {}       # bare class name -> qualname
        self.class_methods: Dict[str, Set[str]] = {}  # cls qual -> bare names
        self.device_attrs: Set[str] = set()
        self.returns_device: Set[str] = set()
        self._parse()
        self._index()
        self._resolve_calls()
        self._device_fixpoint()

    # ------------------------------------------------------------------
    def _parse(self) -> None:
        for p in sorted(self.src_dir.rglob("*.py")):
            rel = p.relative_to(self.src_dir)
            parts = list(rel.parts[:-1])
            stem = rel.parts[-1][:-3]
            if stem != "__init__":
                parts.append(stem)
            mod = ".".join(parts) if parts else stem
            try:
                src = p.read_text()
                tree = ast.parse(src)
            except (SyntaxError, UnicodeDecodeError):
                continue
            info = ModuleInfo(mod, p, tree, src)
            for node in ast.walk(tree):
                if isinstance(node, ast.Import):
                    for a in node.names:
                        info.imports[a.asname or a.name.split(".")[0]] = \
                            a.name if a.asname else a.name.split(".")[0]
                elif isinstance(node, ast.ImportFrom) and node.module:
                    for a in node.names:
                        info.imports[a.asname or a.name] = \
                            f"{node.module}.{a.name}"
            self.modules[mod] = info

    def _index(self) -> None:
        for mod, info in self.modules.items():
            for node in info.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._add_function(info, node, cls=None)
                elif isinstance(node, ast.ClassDef):
                    cq = f"{mod}.{node.name}"
                    self.classes.setdefault(node.name, cq)
                    names = self.class_methods.setdefault(cq, set())
                    for sub in node.body:
                        if isinstance(sub, (ast.FunctionDef,
                                            ast.AsyncFunctionDef)):
                            self._add_function(info, sub, cls=node.name)
                            names.add(sub.name)

    def _add_function(self, info: ModuleInfo, node, cls: Optional[str]):
        qual = (f"{info.name}.{cls}.{node.name}" if cls
                else f"{info.name}.{node.name}")
        params = tuple(a.arg for a in node.args.posonlyargs + node.args.args
                       if a.arg not in ("self", "cls"))
        fi = FunctionInfo(qual, node.name, info.name, cls, node, info.path,
                          params=params)
        for deco in node.decorator_list:
            ji = _jit_info(deco)
            if ji is not None:
                fi.is_jitted = True
                fi.static_argnames = ji[0]
        self.functions[qual] = fi
        self.by_name.setdefault(node.name, []).append(qual)

    # ------------------------------------------------------------------
    def resolve_call(self, fi: FunctionInfo, call: ast.Call) -> Set[str]:
        """Project qualnames a call MAY dispatch to (over-approximate)."""
        out: Set[str] = set()
        func = call.func
        info = self.modules[fi.module]
        if isinstance(func, ast.Name):
            target = info.imports.get(func.id, f"{fi.module}.{func.id}")
            if target in self.functions:
                out.add(target)
            # class instantiation -> its init hooks
            cq = (target if target in self.class_methods
                  else self.classes.get(func.id))
            if cq:
                for init in ("__init__", "__post_init__"):
                    q = f"{cq}.{init}"
                    if q in self.functions:
                        out.add(q)
        elif isinstance(func, ast.Attribute):
            d = dotted_name(func)
            if d:
                root, _, rest = d.partition(".")
                full = f"{info.imports.get(root, root)}.{rest}" if rest else d
                if full in self.functions:
                    out.add(full)
            if not out:
                # self.method(): same-class resolution first
                if (isinstance(func.value, ast.Name)
                        and func.value.id == "self" and fi.cls):
                    cq = f"{fi.module}.{fi.cls}"
                    if func.attr in self.class_methods.get(cq, set()):
                        out.add(f"{cq}.{func.attr}")
                        return out
                out.update(self.by_name.get(func.attr, ()))
        return out

    def _resolve_calls(self) -> None:
        for fi in self.functions.values():
            for node in ast.walk(fi.node):
                if isinstance(node, ast.Call):
                    fi.calls |= self.resolve_call(fi, node)

    def reachable(self, roots: Iterable[str]) -> Set[str]:
        seen: Set[str] = set()
        frontier = [r for r in roots if r in self.functions]
        while frontier:
            q = frontier.pop()
            if q in seen:
                continue
            seen.add(q)
            frontier.extend(self.functions[q].calls - seen)
        return seen

    # ------------------------------------------------------------------
    # device taint
    # ------------------------------------------------------------------
    def _device_fixpoint(self) -> None:
        """Iterate attribute taint and returns-device to a fixed point
        (attribute assignments and returns feed each other)."""
        self.returns_device = {q for q, f in self.functions.items()
                               if f.is_jitted}
        for _ in range(6):
            attrs = self._collect_device_attrs()
            rets = set(self.returns_device)
            for q, fi in self.functions.items():
                if q in rets:
                    continue
                taint = DeviceTaint(self, fi)
                env = taint.build_env()
                for node in _walk_own(fi.node):
                    if isinstance(node, ast.Return) and node.value is not None:
                        if taint.is_device(node.value, env):
                            rets.add(q)
                            break
            if attrs == self.device_attrs and rets == self.returns_device:
                break
            self.device_attrs = attrs
            self.returns_device = rets

    def _collect_device_attrs(self) -> Set[str]:
        attrs: Set[str] = set()
        for fi in self.functions.values():
            taint = DeviceTaint(self, fi)
            env = taint.build_env()
            for node in ast.walk(fi.node):
                if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                    value = node.value
                    if value is None:
                        continue
                    targets = (node.targets if isinstance(node, ast.Assign)
                               else [node.target])
                    if taint.is_device(value, env):
                        for t in targets:
                            if isinstance(t, ast.Attribute):
                                attrs.add(t.attr)
        # dataclass field annotations: ``x: Array = ...`` in class bodies
        for info in self.modules.values():
            for node in ast.walk(info.tree):
                if isinstance(node, ast.ClassDef):
                    for sub in node.body:
                        if (isinstance(sub, ast.AnnAssign)
                                and isinstance(sub.target, ast.Name)):
                            ann = dotted_name(sub.annotation) or ""
                            if ann in DEVICE_PARAM_ANNOTATIONS:
                                attrs.add(sub.target.id)
        return attrs

    def canonical(self, fi: FunctionInfo, dotted: str) -> str:
        """Resolve the first segment of a dotted path through the
        module's import aliases: ``jnp.argmax`` -> ``jax.numpy.argmax``."""
        root, _, rest = dotted.partition(".")
        root = self.modules[fi.module].imports.get(root, root)
        return f"{root}.{rest}" if rest else root


def _walk_own(fn_node: ast.AST):
    """Walk a function body WITHOUT descending into nested function
    definitions (their returns are not this function's returns)."""
    stack = list(ast.iter_child_nodes(fn_node))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


class DeviceTaint:
    """Per-function device-value classifier over a name environment."""

    def __init__(self, project: Project, fi: FunctionInfo):
        self.project = project
        self.fi = fi

    # -- environment ---------------------------------------------------
    def build_env(self) -> Set[str]:
        """Names holding device values.  Two forward passes approximate
        loop-carried flow; the LAST binding of a name wins (rebinding a
        name to a host value cleans it)."""
        env: Set[str] = set()
        args = self.fi.node.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            ann = dotted_name(a.annotation) if a.annotation else None
            if ann in DEVICE_PARAM_ANNOTATIONS:
                env.add(a.arg)
        for _ in range(2):
            self._pass_stmts(self.fi.node.body, env)
        return env

    def _bind(self, target: ast.AST, device: bool, env: Set[str]) -> None:
        if isinstance(target, ast.Name):
            (env.add if device else env.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, device, env)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, device, env)
        elif isinstance(target, ast.Subscript) and device:
            # storing a device value INTO a container taints the container
            base = target.value
            while isinstance(base, ast.Subscript):
                base = base.value
            if isinstance(base, ast.Name):
                env.add(base.id)

    def _pass_stmts(self, stmts, env: Set[str]) -> None:
        for st in stmts:
            if isinstance(st, ast.Assign):
                dev = self.is_device(st.value, env)
                for t in st.targets:
                    self._bind(t, dev, env)
            elif isinstance(st, ast.AnnAssign) and st.value is not None:
                self._bind(st.target, self.is_device(st.value, env), env)
            elif isinstance(st, ast.AugAssign):
                if self.is_device(st.value, env):
                    self._bind(st.target, True, env)
            elif isinstance(st, ast.For):
                if self.is_device(st.iter, env):
                    self._bind(st.target, True, env)
                self._pass_stmts(st.body + st.orelse, env)
            elif isinstance(st, (ast.While, ast.If)):
                self._pass_stmts(st.body + st.orelse, env)
            elif isinstance(st, ast.With):
                self._pass_stmts(st.body, env)
            elif isinstance(st, ast.Try):
                self._pass_stmts(st.body + st.orelse + st.finalbody, env)
                for h in st.handlers:
                    self._pass_stmts(h.body, env)
            elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # closures see (and run inside) the enclosing flow
                self._pass_stmts(st.body, env)

    # -- classification ------------------------------------------------
    def is_device(self, expr: ast.AST, env: Set[str]) -> bool:
        if isinstance(expr, ast.Name):
            return expr.id in env
        if isinstance(expr, ast.Attribute):
            if expr.attr in HOST_META_ATTRS:
                return False
            return (expr.attr in self.project.device_attrs
                    or self.is_device(expr.value, env))
        if isinstance(expr, ast.Subscript):
            return self.is_device(expr.value, env)
        if isinstance(expr, ast.Call):
            return self._call_device(expr, env)
        if isinstance(expr, ast.BinOp):
            return (self.is_device(expr.left, env)
                    or self.is_device(expr.right, env))
        if isinstance(expr, ast.UnaryOp):
            return self.is_device(expr.operand, env)
        if isinstance(expr, ast.Compare):
            return (self.is_device(expr.left, env)
                    or any(self.is_device(c, env) for c in expr.comparators))
        if isinstance(expr, ast.BoolOp):
            return any(self.is_device(v, env) for v in expr.values)
        if isinstance(expr, ast.IfExp):
            return (self.is_device(expr.body, env)
                    or self.is_device(expr.orelse, env))
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            return any(self.is_device(e, env) for e in expr.elts)
        if isinstance(expr, ast.Dict):
            return any(v is not None and self.is_device(v, env)
                       for v in expr.values)
        if isinstance(expr, ast.NamedExpr):
            return self.is_device(expr.value, env)
        if isinstance(expr, ast.Starred):
            return self.is_device(expr.value, env)
        if isinstance(expr, (ast.DictComp, ast.ListComp, ast.SetComp)):
            val = expr.value if isinstance(expr, ast.DictComp) else expr.elt
            return self.is_device(val, env)
        return False

    def _call_device(self, call: ast.Call, env: Set[str]) -> bool:
        d = dotted_name(call.func)
        if d:
            full = self.project.canonical(self.fi, d)
            if full in HOST_SAFE_CALLS:
                return False
            if full == "jax" or full.startswith(("jax.", "jax_")):
                return True
            if full.startswith("numpy.") or full == "numpy":
                return False
        targets = self.project.resolve_call(self.fi, call)
        if targets & self.project.returns_device:
            return True
        # method call on a device value: x.astype(...), x.reshape(...)
        if isinstance(call.func, ast.Attribute):
            if call.func.attr in HOST_RESULT_METHODS:
                return False
            if self.is_device(call.func.value, env):
                return True
        return False
