"""Baseline store: suppress KNOWN findings, fail on NEW ones.

``analysis-baseline.json`` is committed at the repo root and holds

  suppressions          {fingerprint: {count, checker, rule, path,
                        symbol, message}} — the accepted debt.  The
                        fingerprint excludes line numbers (see
                        ``findings``), so unrelated edits don't churn
                        it; a count>1 covers duplicated snippets.
  granularity_contract  the pinned tile sizes the drift checker
                        compares against (never suppressible).

``--check-baseline`` exits non-zero iff a finding's fingerprint count
exceeds its suppressed count.  STALE suppressions (debt that got fixed)
are reported informationally — regenerate with ``--write-baseline`` to
drop them, which is also how a satellite fix is "recorded by removing
its baseline entry".
"""
from __future__ import annotations

import json
from collections import Counter
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Finding

BASELINE_NAME = "analysis-baseline.json"
VERSION = 1

# drift findings can only be cleared by updating the pinned contract
NEVER_SUPPRESS = {"granularity-drift"}


def load_baseline(path: Path) -> Dict:
    path = Path(path)
    if not path.exists():
        return {"version": VERSION, "suppressions": {},
                "granularity_contract": {}}
    data = json.loads(path.read_text())
    data.setdefault("suppressions", {})
    data.setdefault("granularity_contract", {})
    return data


def write_baseline(path: Path, findings: List[Finding],
                   contract: Optional[Dict[str, int]] = None) -> Dict:
    sup: Dict[str, Dict] = {}
    for f in findings:
        if f.checker in NEVER_SUPPRESS:
            continue
        entry = sup.setdefault(f.fingerprint, {
            "count": 0, "checker": f.checker, "rule": f.rule,
            "path": f.path, "symbol": f.symbol, "message": f.message,
        })
        entry["count"] += 1
    data = {
        "version": VERSION,
        "_comment": ("Known findings of `python -m repro.analysis` — "
                     "suppressed debt, not a license. New findings fail "
                     "--check-baseline; regenerate ONLY via "
                     "--write-baseline so review sees the diff."),
        "granularity_contract": dict(sorted((contract or {}).items())),
        "suppressions": dict(sorted(sup.items())),
    }
    Path(path).write_text(json.dumps(data, indent=2, sort_keys=False)
                          + "\n")
    return data


def diff_against_baseline(findings: List[Finding], baseline: Dict
                          ) -> Tuple[List[Finding], List[Finding],
                                     List[Dict]]:
    """(new, suppressed, stale): findings beyond the baselined count,
    findings the baseline absorbs, and baseline entries with no match
    left in the tree."""
    sup = baseline.get("suppressions", {})
    seen: Counter = Counter()
    new: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        fp = f.fingerprint
        seen[fp] += 1
        allowed = 0 if f.checker in NEVER_SUPPRESS else \
            int(sup.get(fp, {}).get("count", 0))
        (suppressed if seen[fp] <= allowed else new).append(f)
    stale = [dict(entry, fingerprint=fp) for fp, entry in sup.items()
             if seen.get(fp, 0) < int(entry.get("count", 0))]
    return new, suppressed, stale
