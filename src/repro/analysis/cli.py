"""CLI: ``python -m repro.analysis [--check-baseline|--write-baseline]``.

Exit codes: 0 clean (or informational run), 1 usage/internal error,
2 NEW findings under ``--check-baseline`` (the CI gate).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.analysis import baseline as baseline_mod
from repro.analysis import (granularity_drift, host_sync, pallas_contracts,
                            recompile)
from repro.analysis.callgraph import Project
from repro.analysis.findings import Finding, sort_findings

CHECKERS = ("host-sync", "recompile-hazard", "pallas-contract",
            "granularity-drift")


def find_repo_root(start: Optional[Path] = None) -> Path:
    """Nearest ancestor holding a ``src`` dir with ``pyproject.toml``;
    falls back to this package's own checkout."""
    probes = []
    if start is not None:
        probes.append(Path(start).resolve())
    probes.append(Path.cwd())
    probes.append(Path(__file__).resolve().parents[3])
    for probe in probes:
        for cand in (probe, *probe.parents):
            if (cand / "pyproject.toml").exists() and (cand / "src").is_dir():
                return cand
    return Path(__file__).resolve().parents[3]


def run_checkers(src_dir: Path, checkers: Sequence[str] = CHECKERS,
                 roots: Sequence[str] = host_sync.DEFAULT_ROOTS,
                 rel_to: Optional[Path] = None,
                 contract: Optional[Dict[str, int]] = None,
                 captures=None) -> List[Finding]:
    """Run the named checkers over the tree under ``src_dir``."""
    findings: List[Finding] = []
    need_ast = {"host-sync", "recompile-hazard"} & set(checkers)
    project = Project(src_dir, rel_to=rel_to) if need_ast else None
    if "host-sync" in checkers:
        findings += host_sync.check(project, roots=roots)
    if "recompile-hazard" in checkers:
        findings += recompile.check(project)
    need_capture = {"pallas-contract", "granularity-drift"} & set(checkers)
    if need_capture and captures is None:
        captures = pallas_contracts.capture_launches()
    if "pallas-contract" in checkers:
        findings += pallas_contracts.check(captures=captures)
    if "granularity-drift" in checkers:
        findings += granularity_drift.check(captures=captures,
                                            contract=contract)
    return sort_findings(findings)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Hot-path static analysis: host syncs, recompile "
                    "hazards, Pallas launch contracts, granularity drift.")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detect)")
    ap.add_argument("--checkers", default=",".join(CHECKERS),
                    help="comma-separated subset of: " + ", ".join(CHECKERS))
    ap.add_argument("--roots", default=",".join(host_sync.DEFAULT_ROOTS),
                    help="hot-path entry points for host-sync reachability")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline path (default <root>/"
                         f"{baseline_mod.BASELINE_NAME})")
    ap.add_argument("--check-baseline", action="store_true",
                    help="exit 2 if any finding is not in the baseline "
                         "(the CI gate)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline (suppressions + pinned "
                         "granularity contract) from the current tree")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable JSON on stdout")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also list baseline/pragma-suppressed findings")
    args = ap.parse_args(argv)

    root = find_repo_root(args.root)
    src_dir = root / "src"
    if not src_dir.is_dir():
        print(f"error: no src/ under {root}", file=sys.stderr)
        return 1
    checkers = [c.strip() for c in args.checkers.split(",") if c.strip()]
    bad = [c for c in checkers if c not in CHECKERS]
    if bad:
        print(f"error: unknown checkers {bad}; valid: {list(CHECKERS)}",
              file=sys.stderr)
        return 1
    roots = [r.strip() for r in args.roots.split(",") if r.strip()]
    bl_path = args.baseline or root / baseline_mod.BASELINE_NAME
    bl = baseline_mod.load_baseline(bl_path)

    need_capture = {"pallas-contract", "granularity-drift"} & set(checkers)
    captures = pallas_contracts.capture_launches() if need_capture else None
    findings = run_checkers(src_dir, checkers, roots=roots, rel_to=root,
                            contract=bl.get("granularity_contract"),
                            captures=captures)

    if args.write_baseline:
        contract = granularity_drift.declared_tiles()
        data = baseline_mod.write_baseline(bl_path, findings, contract)
        print(f"wrote {bl_path}: {sum(e['count'] for e in data['suppressions'].values())} "
              f"suppressed finding(s), contract {contract}")
        return 0

    new, suppressed, stale = baseline_mod.diff_against_baseline(findings, bl)
    shown = new if args.check_baseline else findings

    if args.as_json:
        print(json.dumps({
            "findings": [f.as_dict() for f in shown],
            "suppressed": [f.as_dict() for f in suppressed],
            "stale_suppressions": stale,
            "checkers": checkers,
        }, indent=2))
    else:
        for f in shown:
            print(f.render())
        if args.show_suppressed:
            for f in suppressed:
                print(f"[baseline] {f.render()}")
        summary = (f"{len(findings)} finding(s): {len(new)} new, "
                   f"{len(suppressed)} baselined")
        if stale:
            summary += (f"; {len(stale)} stale baseline entr"
                        f"{'y' if len(stale) == 1 else 'ies'} "
                        "(fixed debt — regenerate with --write-baseline)")
        print(summary)

    if args.check_baseline and new:
        if not args.as_json:
            print(f"FAIL: {len(new)} new finding(s) not in "
                  f"{bl_path.name}", file=sys.stderr)
        return 2
    return 0
