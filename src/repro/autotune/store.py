"""Calibration artifact schema + persistence.

A ``CalibrationTable`` is the durable product of one calibration run
(``autotune.calibrate``): per (serve mode, context-length bucket,
kernel on/off) it records the measured T(N) curve, the empirical knee,
and the analytic prediction it refines.  Tables round-trip through JSON
so a calibration run on real hardware can be shipped with a deployment
and loaded at serve time (``launch.serve --calibration load``).

Artifacts are keyed by a fingerprint of everything the curve depends
on: the architecture config, the hardware spec, the granularity spec,
the kernel flags the sweep covered, the batch (slot count), and the
tolerance eps.  Loading an artifact whose key does not match the
current engine REFUSES with a clear error instead of silently applying
budgets calibrated for a different model/hardware — a stale budget that
over-spends positions is exactly the failure mode calibration exists to
remove.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

SCHEMA_VERSION = 1

__all__ = ["SCHEMA_VERSION", "CalibrationEntry", "CalibrationTable",
           "CalibrationMismatchError", "spec_fingerprint", "save_table",
           "load_table"]


class CalibrationMismatchError(ValueError):
    """A calibration artifact does not match the current engine spec."""


def spec_fingerprint(cfg, hw, gran, kernel_flags, batch: int,
                     eps: float) -> str:
    """Stable hash of everything a calibration curve depends on.

    ``cfg`` / ``hw`` / ``gran`` are the (frozen) ArchConfig /
    HardwareSpec / GranularitySpec dataclasses; ``kernel_flags`` the
    kernel settings the sweep covered.  Any change to any field — a new
    head count, a different HBM bandwidth, a different KV page size —
    changes the key, so stale artifacts cannot load.
    """
    payload = {
        "schema": SCHEMA_VERSION,
        "arch": dataclasses.asdict(cfg),
        "hardware": dataclasses.asdict(hw),
        "granularity": dataclasses.asdict(gran),
        "kernel_flags": sorted({bool(k) for k in kernel_flags}),
        "batch": int(batch),
        "eps": round(float(eps), 6),
    }
    blob = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


@dataclass
class CalibrationEntry:
    """One calibrated (mode, context bucket, kernel) cell.

    ``measured_nmax`` and ``analytic_nmax`` are both in WIDTH currency:
    decode positions per slot row of one (batch, N)-shaped forward —
    the same N the paper's Eq. 4 extracts and ``parallelism_budget``
    predicts at this batch.
    """

    mode: str                   # serve mode the entry was swept for
    ell: int                    # context-length bucket (positions)
    use_kernel: bool            # Pallas decode kernel on/off
    eps: float                  # tolerance the knee was extracted at
    ns: List[int]               # sampled widths
    times: List[float]          # T(N) seconds per forward
    spreads: List[float]        # relative per-round spread (0 = exact)
    baseline_time: float        # T(1) — the width-1 serving baseline
    noise: float                # max relative spread (variance-gate floor)
    measured_nmax: int          # empirical knee (contiguous extraction)
    analytic_nmax: int          # core.nfp.parallelism_budget at this bucket
    n_idle: float               # pure idle-compute intuition (Table 24)
    limiting: str               # predict_model's limiting term

    @property
    def calibrated_budget(self) -> int:
        """Calibration refines the analytic budget DOWNWARD only: the
        measured knee is trusted when it is earlier than the analytic
        boundary (the paper's over-prediction finding), but a knee
        sampled PAST the analytic boundary never raises the budget —
        the analytic min already encodes granularity facts a coarse
        sweep can miss between samples."""
        return max(1, min(self.measured_nmax, self.analytic_nmax))

    @property
    def overprediction(self) -> float:
        """How far the analytic budget over-predicts the deployable one
        (>= 1 by construction of ``calibrated_budget``)."""
        return self.analytic_nmax / self.calibrated_budget

    @property
    def idle_overprediction(self) -> float:
        """The paper's Table 24 ratio: idle-compute intuition vs the
        calibrated boundary (up to ~23x)."""
        if not (self.n_idle == self.n_idle):          # NaN guard
            return float("inf")
        return self.n_idle / self.calibrated_budget


@dataclass
class CalibrationTable:
    """All calibration entries for one (arch, hardware, batch, eps)."""

    key: str
    arch: str
    hardware: str
    batch: int
    eps: float
    backend: str                # "simulator" | "wallclock"
    entries: List[CalibrationEntry] = field(default_factory=list)

    # ------------------------------------------------------------------
    def _candidates(self, mode: Optional[str],
                    use_kernel: Optional[bool]) -> List[CalibrationEntry]:
        es = [e for e in self.entries
              if use_kernel is None or e.use_kernel == bool(use_kernel)]
        exact = [e for e in es if mode is None or e.mode == mode]
        # the decode forward is mode-independent, so a table calibrated
        # for other modes is still a valid latency model — fall back
        # rather than flying blind
        return exact or es

    def lookup(self, mode: Optional[str], ell: int,
               use_kernel: Optional[bool] = None
               ) -> Optional[CalibrationEntry]:
        """Entry for the smallest bucket >= ell (conservative: boundaries
        shrink as context grows), else the largest bucket."""
        cands = self._candidates(mode, use_kernel)
        if not cands:
            return None
        above = [e for e in cands if e.ell >= ell]
        pool = above or cands
        return min(pool, key=lambda e: (e.ell if above else -e.ell))

    def budget(self, mode: Optional[str], ell: int,
               use_kernel: Optional[bool] = None) -> Optional[int]:
        e = self.lookup(mode, ell, use_kernel)
        return e.calibrated_budget if e is not None else None

    def baseline(self, mode: Optional[str], ell: int,
                 use_kernel: Optional[bool] = None
                 ) -> Optional[Tuple[float, float]]:
        """(width-1 latency, noise floor) for seeding the controller."""
        e = self.lookup(mode, ell, use_kernel)
        return (e.baseline_time, e.noise) if e is not None else None

    def buckets(self) -> List[int]:
        return sorted({e.ell for e in self.entries})

    # ------------------------------------------------------------------
    def to_json(self) -> Dict:
        return {
            "schema": SCHEMA_VERSION,
            "key": self.key,
            "arch": self.arch,
            "hardware": self.hardware,
            "batch": self.batch,
            "eps": self.eps,
            "backend": self.backend,
            "entries": [dataclasses.asdict(e) for e in self.entries],
        }

    @classmethod
    def from_json(cls, data: Dict) -> "CalibrationTable":
        return cls(
            key=data["key"], arch=data["arch"], hardware=data["hardware"],
            batch=int(data["batch"]), eps=float(data["eps"]),
            backend=data.get("backend", "unknown"),
            entries=[CalibrationEntry(**e) for e in data["entries"]],
        )


# ---------------------------------------------------------------------------
# Persistence
# ---------------------------------------------------------------------------

def save_table(table: CalibrationTable, path: str) -> None:
    with open(path, "w") as f:
        json.dump(table.to_json(), f, indent=1, sort_keys=True)


def load_table(path: str, expect_key: Optional[str] = None
               ) -> CalibrationTable:
    """Load an artifact, refusing schema/key mismatches loudly."""
    with open(path) as f:
        data = json.load(f)
    if data.get("schema") != SCHEMA_VERSION:
        raise CalibrationMismatchError(
            f"calibration artifact {path} has schema version "
            f"{data.get('schema')!r}, this build reads {SCHEMA_VERSION}; "
            "re-run with --calibration run to refresh it")
    table = CalibrationTable.from_json(data)
    if expect_key is not None and table.key != expect_key:
        raise CalibrationMismatchError(
            f"stale calibration artifact {path}: calibrated under key "
            f"{table.key} (arch={table.arch}, hardware={table.hardware}, "
            f"batch={table.batch}, eps={table.eps}) but the current engine "
            f"spec hashes to {expect_key}.  The arch config, hardware "
            "spec, granularity (incl. KV page size), kernel flags, slot "
            "count, or eps changed since calibration — re-run with "
            "--calibration run")
    return table
