"""Offline empirical NFP calibration.

The analytic budget (``core.nfp.parallelism_budget``) is a closed-form
prediction; the paper's headline is that closed-form intuitions
over-predict the practical boundary (idle-compute by up to 23x).  This
module closes the loop: it sweeps T(N) on the engine the scheduler will
actually serve with — per (serve mode, context-length bucket, kernel
on/off) — extracts the empirical knee with the paper's Eq. 4 protocol
(``core.measure``), and records measured vs analytic boundaries in a
``CalibrationTable`` the online ``BudgetController`` consumes.

Latency sources ("backends"):

  wallclock   times live ``DecodeEngine.decode_slots`` forwards with
              the App. C.1.2 protocol (warmup, R rounds x I iters,
              median of round medians + per-round spread).  Only
              meaningful on an accelerator.
  simulator   the roofline + granularity latency model
              (``core.simulate``) — the TPU-target fallback when the
              host has no accelerator (exactly the substitute the
              benchmarks use), deterministic with zero spread.

The serving baseline is ALWAYS width 1 at the engine's full batch: the
knee answers "how many positions per slot row can one (batch, N)
forward carry before a width-1 step's latency grows past (1+eps)" —
the quantity the scheduler trades against.  (This is deliberately NOT
the paper's Eq. 26 balanced-MoE baseline: at serve time the width-1
step is what a user-visible token costs, so budgets that activate more
experts than width-1 does must pay for it.)  The knee uses
``extract_nmax(contiguous=True)`` so a noisy rebound past the boundary
cannot inflate it.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.granularity import GranularitySpec
from repro.core.measure import LatencyCurve, extract_nmax, time_callable
from repro.core.nfp import parallelism_budget, predict_model
from repro.core.simulate import decode_forward_cost

from repro.autotune.store import (CalibrationEntry, CalibrationTable,
                                  spec_fingerprint)

__all__ = ["DEFAULT_MODES", "context_buckets", "width_grid",
           "simulator_time_fn", "calibrate_specs", "calibrate_engine"]

DEFAULT_MODES = ("greedy", "speculative", "mtp", "diffusion")

# context-length ladder: powers of 4 — boundaries move slowly in ell
# (the attention idle term is the only ell-dependent one), so coarse
# buckets keep sweep cost low without losing the knee's ell trend
CONTEXT_LADDER = (64, 256, 1024, 4096, 16384, 65536)

# TimeFn(n, ell, use_kernel) -> (seconds per forward, relative spread)
TimeFn = Callable[[int, int, bool], Tuple[float, float]]


def context_buckets(max_len: int) -> List[int]:
    """Ladder buckets below ``max_len``, plus ``max_len`` itself."""
    bs = [b for b in CONTEXT_LADDER if b < max_len]
    return bs + [int(max_len)]


def width_grid(cap: int = 128) -> List[int]:
    """Sampled widths: dense at small N (the knees live there), then
    tile-boundary landmarks with one-past probes (16/64 + 1)."""
    ns = list(range(1, 9)) + [12, 16, 17, 24, 32, 48, 64, 65, 96, 128]
    return sorted({n for n in ns if n <= max(cap, 2)} | {1, 2})


def simulator_time_fn(cfg, hw, gran: GranularitySpec, batch: int,
                      routing: str = "balanced") -> TimeFn:
    """Roofline-simulator latency source (deterministic, zero spread)."""
    def fn(n: int, ell: int, use_kernel: bool) -> Tuple[float, float]:
        return (decode_forward_cost(cfg, batch, n, ell, gran, routing)
                .time(hw), 0.0)
    return fn


# ---------------------------------------------------------------------------
# Core sweep: pure specs + a latency source
# ---------------------------------------------------------------------------

def calibrate_specs(cfg, hw, gran: GranularitySpec, batch: int,
                    max_len: int = 4096,
                    modes: Sequence[str] = DEFAULT_MODES,
                    kernels: Sequence[bool] = (False,),
                    eps: float = 0.2,
                    buckets: Optional[Sequence[int]] = None,
                    ns: Optional[Sequence[int]] = None,
                    time_fn: Optional[TimeFn] = None,
                    backend: str = "simulator",
                    routing: str = "balanced") -> CalibrationTable:
    """Calibrate from specs alone against any latency source.

    One T(N) sweep runs per (context bucket, kernel flag); the decode
    forward itself is serve-mode independent, so every requested mode
    shares that curve — the mode axis exists in the artifact so the
    controller's lookup is explicit about what it serves (and so future
    mode-specific latency sources can fill it without a schema change).
    """
    if time_fn is None:
        time_fn = simulator_time_fn(cfg, hw, gran, batch, routing)
    buckets = list(buckets) if buckets else context_buckets(max_len)
    ns = list(ns) if ns else width_grid()
    entries: List[CalibrationEntry] = []
    for use_kernel in kernels:
        for ell in buckets:
            times, spreads = [], []
            for n in ns:
                t, spread = time_fn(int(n), int(ell), bool(use_kernel))
                times.append(float(t))
                spreads.append(float(spread))
            curve = LatencyCurve(ns, times, baseline_n=1, spreads=spreads)
            measured = extract_nmax(curve, eps, contiguous=True)
            analytic = parallelism_budget(cfg, hw, gran, batch, int(ell),
                                          eps, routing)
            pred = predict_model(cfg, hw, gran, batch, int(ell), routing)
            for mode in modes:
                entries.append(CalibrationEntry(
                    mode=mode, ell=int(ell), use_kernel=bool(use_kernel),
                    eps=float(eps), ns=[int(n) for n in ns], times=times,
                    spreads=spreads, baseline_time=curve.baseline_time,
                    noise=curve.max_spread, measured_nmax=int(measured),
                    analytic_nmax=int(analytic), n_idle=float(pred.n_idle),
                    limiting=pred.limiting))
    key = spec_fingerprint(cfg, hw, gran, kernels, batch, eps)
    return CalibrationTable(key=key, arch=cfg.name, hardware=hw.name,
                            batch=int(batch), eps=float(eps),
                            backend=backend, entries=entries)


# ---------------------------------------------------------------------------
# Live-engine calibration
# ---------------------------------------------------------------------------

def _wallclock_time_fn(engine, warmup: int, rounds: int,
                       iters: int) -> TimeFn:
    """Times real ``decode_slots`` forwards on the live engine: every
    slot row at cache length ell, one (batch, n) forward, no commit.
    Engine state (slot lengths, kernel flag) is saved and restored
    around each sample, so calibration can run on a warm engine."""
    import jax.numpy as jnp
    import numpy as np

    def fn(n: int, ell: int, use_kernel: bool) -> Tuple[float, float]:
        saved_lens = engine.slot_lens
        saved_lens_host = engine.slot_lens_host.copy()
        saved_kernel = engine.use_kernel
        try:
            engine.slot_lens = jnp.full((engine.batch,), ell, jnp.int32)
            engine.slot_lens_host = np.full((engine.batch,), ell, np.int64)
            engine.use_kernel = use_kernel
            toks = jnp.zeros((engine.batch, n), jnp.int32)
            return time_callable(lambda: engine.decode_slots(toks),
                                 warmup, rounds, iters)
        finally:
            engine.slot_lens = saved_lens
            engine.slot_lens_host = saved_lens_host
            engine.use_kernel = saved_kernel
    return fn


def calibrate_engine(engine, modes: Sequence[str] = DEFAULT_MODES,
                     kernels: Optional[Sequence[bool]] = None,
                     eps: float = 0.2,
                     buckets: Optional[Sequence[int]] = None,
                     ns: Optional[Sequence[int]] = None,
                     backend: str = "auto",
                     warmup: int = 2, rounds: int = 3, iters: int = 5,
                     ) -> CalibrationTable:
    """Calibrate a live ``DecodeEngine``.

    ``backend="auto"`` picks wallclock on an accelerator and the
    roofline simulator on CPU hosts (wall-clock CPU timings of a
    TPU-target model say nothing about the TPU knee).
    """
    if backend == "auto":
        import jax
        backend = ("wallclock" if jax.default_backend() in ("gpu", "tpu")
                   else "simulator")
    if kernels is None:
        kernels = (engine.use_kernel,)
    if ns is None:
        # a decode forward at bucket ell writes positions ell..ell+n-1,
        # so the width grid must leave headroom inside the engine's
        # cache even at the largest bucket
        ns = width_grid(cap=min(128, max(2, engine.max_len // 2)))
    max_n = max(ns)
    if max_n >= engine.max_len:
        raise ValueError(
            f"width grid reaches {max_n} but the engine cache holds only "
            f"{engine.max_len} positions; pass a smaller ns")
    if buckets is None:
        buckets = sorted({min(b, engine.max_len - max_n)
                          for b in context_buckets(engine.max_len)})
        buckets = [b for b in buckets if b >= 1]
        if not buckets:       # unreachable: max_len - max_n >= 1 above
            raise RuntimeError("derived an empty context-bucket grid")
    if backend == "wallclock":
        if max(buckets) + max_n > engine.max_len:
            raise ValueError(
                f"bucket {max(buckets)} + width {max_n} overruns the "
                f"engine's {engine.max_len}-position cache; live sweeps "
                "need ell + n <= max_len")
        if engine.manager is not None:
            raise ValueError(
                "wallclock calibration drives synthetic cache lengths "
                "through decode_slots, which a paged engine cannot serve "
                "without real block tables — calibrate a dense engine of "
                "the same config, or use backend='simulator'")
        time_fn = _wallclock_time_fn(engine, warmup, rounds, iters)
    else:
        backend = "simulator"
        time_fn = simulator_time_fn(engine.cfg, engine.hardware,
                                    engine.gran, engine.batch)
    return calibrate_specs(engine.cfg, engine.hardware, engine.gran,
                           engine.batch, max_len=engine.max_len,
                           modes=modes, kernels=kernels, eps=eps,
                           buckets=buckets, ns=ns, time_fn=time_fn,
                           backend=backend)
