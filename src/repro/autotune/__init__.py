"""repro.autotune — empirical NFP calibration + online budget control.

Closes the loop the analytic predictor leaves open: ``calibrate``
measures the practical near-free boundary on the live engine (roofline
simulator fallback on CPU hosts), ``store`` persists the result as a
spec-keyed artifact, and ``controller`` adapts the serving loop's
position budget online against observed step latency.

  calibrate:  calibrate_engine / calibrate_specs -> CalibrationTable
  store:      save_table / load_table / spec_fingerprint (stale-key
              refusal via CalibrationMismatchError)
  controller: BudgetController (AIMD, variance-gated, per-context-
              bucket) — plug into ServingLoop(controller=...)
"""
from repro.autotune.calibrate import (DEFAULT_MODES, calibrate_engine,
                                      calibrate_specs, context_buckets,
                                      simulator_time_fn, width_grid)
from repro.autotune.controller import BudgetController, ControllerConfig
from repro.autotune.store import (CalibrationEntry, CalibrationMismatchError,
                                  CalibrationTable, load_table, save_table,
                                  spec_fingerprint)

__all__ = ["DEFAULT_MODES", "BudgetController", "CalibrationEntry",
           "CalibrationMismatchError", "CalibrationTable",
           "ControllerConfig", "calibrate_engine", "calibrate_specs",
           "context_buckets", "load_table", "save_table",
           "simulator_time_fn", "spec_fingerprint", "width_grid"]
