"""Online adaptive budget control for the serving loop.

``BudgetController`` is what ``ServingLoop`` consults instead of the
raw analytic ``engine.nfp_budget``: it owns a per-context-bucket
near-free WIDTH (decode positions per slot row) and adapts it AIMD-
style against the latency the loop actually observes:

  baseline   an EMA of per-forward latency at width 1 — the user-
             visible cost of one token, the denominator of the paper's
             Eq. 4 tolerance.  Seeded from a ``CalibrationTable`` when
             one is loaded; learned online otherwise (the loop serves
             width-1 steps until a baseline exists).
  shrink     multiplicative decrease when the observed latency ratio
             exceeds (1+eps)*(1+noise) for ``patience`` consecutive
             steps (the variance gate: one noisy spike is not evidence
             the knee moved — ``noise`` is the calibration sweep's own
             measured per-round spread, so the gate reuses the
             measurement path instead of re-deriving a noise model).
  probe      additive increase after a clean step, never within the
             ``cooldown`` window after a shrink, and never past the
             cap.

The width is clamped to ``[1, cap]`` where cap is the analytic budget
per active row — and, when a calibration table is loaded, additionally
the table's calibrated knee: probing past a boundary that was actually
measured would deliberately re-enter the region calibration proved
slow.  With a table, the controller therefore NEVER schedules a width
the calibration curve marked above-tolerance; without one, it is a
slow-start AIMD that converges onto the live knee from below.

Currency note: the controller thinks in width (positions per row); the
scheduler spends a TOTAL position budget.  ``budget()`` converts —
``width * n_active``, floored at one position per active request and
capped by the analytic total — so ``SlotAdapter.width(n_active,
budget)`` recovers exactly the controller's width.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.autotune.store import CalibrationTable

__all__ = ["ControllerConfig", "BudgetController"]


@dataclass
class ControllerConfig:
    eps: float = 0.2             # latency tolerance (the Eq. 4 eps)
    baseline_alpha: float = 0.25  # EMA weight of new width-1 samples
    shrink: float = 0.5          # multiplicative decrease factor
    probe: int = 1               # additive increase step
    cooldown: int = 8            # steps after a shrink before probing up
    patience: int = 2            # consecutive violations before a shrink
    noise_floor: float = 0.0     # minimum relative noise allowance
    baseline_grace: int = 4      # width>1 steps without a baseline before
    #                              falling back to the capped static budget


@dataclass
class _BucketState:
    width: int                   # current near-free width for this bucket
    cap: int                     # last effective cap (table & analytic)
    table_cap: Optional[int]     # calibrated knee (None without a table)
    baseline: Optional[float] = None   # EMA width-1 per-forward latency
    noise: float = 0.0           # relative noise allowance (variance gate)
    cooldown: int = 0
    violations: int = 0          # consecutive above-tolerance steps
    baseline_misses: int = 0     # width>1 observations with no baseline
    ratio_ema: Optional[float] = None
    shrinks: int = 0
    probes: int = 0
    gated: int = 0               # noisy steps the variance gate absorbed


class BudgetController:
    """AIMD near-free budget controller (see module docstring).

    ``mode`` / ``use_kernel`` select the calibration-table rows; the
    ``ServingLoop`` fills them in via ``bind`` when the controller is
    attached, so a freshly constructed ``BudgetController(table)`` is
    enough at the call site.
    """

    def __init__(self, table: Optional[CalibrationTable] = None,
                 config: Optional[ControllerConfig] = None,
                 mode: Optional[str] = None,
                 use_kernel: Optional[bool] = None):
        if config is None:
            config = ControllerConfig(eps=table.eps if table else 0.2)
        self.table = table
        self.config = config
        self.mode = mode
        self.use_kernel = use_kernel
        self._seed_baseline = True
        self._states: Dict[int, _BucketState] = {}

    # ------------------------------------------------------------------
    def bind(self, mode: str, use_kernel: bool,
             clocked: bool = False) -> None:
        """Attach-time defaults (explicit constructor args win).

        ``clocked`` says the loop feeds model-clock latencies rather
        than wall clock.  A table baseline only seeds the EMA when it
        comes from the SAME latency source as the observations —
        simulator seconds against wall-clock seconds would make every
        ratio garbage; when sources differ, the caps and noise floor
        still apply and the baseline is learned online."""
        if self.mode is None:
            self.mode = mode
        if self.use_kernel is None:
            self.use_kernel = bool(use_kernel)
        if self.table is not None:
            self._seed_baseline = clocked == (self.table.backend
                                              == "simulator")

    def _bucket(self, ell: int) -> int:
        """Smallest known bucket >= ell (conservative), else the largest
        — the table's own lookup rule when one is loaded."""
        if self.table is not None:
            entry = self.table.lookup(self.mode, ell, self.use_kernel)
            if entry is not None:
                return entry.ell
        from repro.autotune.calibrate import CONTEXT_LADDER
        above = [b for b in CONTEXT_LADDER if b >= ell]
        return min(above) if above else max(CONTEXT_LADDER)

    def _state(self, ell: int) -> _BucketState:
        b = self._bucket(ell)
        st = self._states.get(b)
        if st is None:
            table_cap = baseline = None
            noise = self.config.noise_floor
            if self.table is not None:
                entry = self.table.lookup(self.mode, b, self.use_kernel)
                if entry is not None:
                    table_cap = entry.calibrated_budget
                    if self._seed_baseline:
                        baseline = entry.baseline_time
                    noise = max(noise, entry.noise)
            # with a table: start AT the calibrated knee (it was measured
            # safe); without: slow-start from 1 and probe up
            st = _BucketState(width=table_cap if table_cap else 1,
                              cap=table_cap if table_cap else 1,
                              table_cap=table_cap, baseline=baseline,
                              noise=noise)
            self._states[b] = st
        return st

    # ------------------------------------------------------------------
    def budget(self, ell: int, n_active: int, analytic: int) -> int:
        """Total position budget for the next step, in the scheduler's
        currency.  Always in [1, max(analytic, n_active)]: the analytic
        budget is the hard cap, but every active request keeps its
        one-position floor (the scheduler's existing admission
        contract)."""
        st = self._state(ell)
        n_active = max(1, int(n_active))
        cap = max(1, int(analytic) // n_active)
        if st.table_cap is not None:
            cap = min(cap, st.table_cap)
        st.cap = cap
        if st.baseline is None:
            if st.baseline_misses < self.config.baseline_grace:
                # no baseline yet: serve width 1 until one exists —
                # these steps ARE the baseline measurement
                return n_active
            # the adapter never runs width-1 forwards (e.g. diffusion
            # with a fixed block size), so no baseline can ever form:
            # fall back to the capped static budget instead of
            # pretending to control
            return min(cap * n_active, max(int(analytic), n_active))
        w = max(1, min(st.width, cap))
        return min(w * n_active, max(int(analytic), n_active))

    def table_budget(self, ell: int, n_active: int,
                     analytic: int) -> Optional[int]:
        """What a STATIC calibrated budget would spend this step (total
        currency, same clamps as ``budget()`` minus the adaptation) —
        the telemetry midpoint between analytic and applied."""
        if self.table is None:
            return None
        w = self.table.budget(self.mode, self._bucket(ell), self.use_kernel)
        if w is None:
            return None
        n_active = max(1, int(n_active))
        return min(w * n_active, max(int(analytic), n_active))

    # ------------------------------------------------------------------
    def observe(self, ell: int, width: int, latency: float
                ) -> Optional[float]:
        """Feed one step's per-forward latency; returns the latency
        ratio vs the width-1 baseline (None when the step itself is a
        baseline sample or no baseline exists yet)."""
        st = self._state(ell)
        cfg = self.config
        latency = float(latency)
        if latency <= 0.0 or not math.isfinite(latency):
            return None
        if width <= 1:
            a = cfg.baseline_alpha
            st.baseline = (latency if st.baseline is None
                           else (1.0 - a) * st.baseline + a * latency)
            st.baseline_misses = 0
            st.violations = 0
            st.cooldown = max(0, st.cooldown - 1)
            self._maybe_probe(st)
            return None
        if st.baseline is None:
            st.baseline_misses += 1
            return None
        ratio = latency / st.baseline
        a = cfg.baseline_alpha
        st.ratio_ema = (ratio if st.ratio_ema is None
                        else (1.0 - a) * st.ratio_ema + a * ratio)
        threshold = (1.0 + cfg.eps) * (1.0 + max(st.noise, cfg.noise_floor))
        if ratio > threshold:
            st.violations += 1
            if st.violations >= cfg.patience:
                st.width = max(1, int(st.width * cfg.shrink))
                st.cooldown = cfg.cooldown
                st.shrinks += 1
                st.violations = 0
            else:
                st.gated += 1         # variance gate: wait for evidence
        else:
            st.violations = 0
            st.cooldown = max(0, st.cooldown - 1)
            self._maybe_probe(st)
        return ratio

    def _maybe_probe(self, st: _BucketState) -> None:
        if st.cooldown == 0 and st.width < st.cap:
            st.width = min(st.width + self.config.probe, st.cap)
            st.probes += 1

    # ------------------------------------------------------------------
    def stats(self) -> Dict:
        out = {"shrinks": 0, "probes": 0, "gated": 0, "buckets": {}}
        for b, st in sorted(self._states.items()):
            out["shrinks"] += st.shrinks
            out["probes"] += st.probes
            out["gated"] += st.gated
            out["buckets"][b] = {
                "width": st.width, "cap": st.cap,
                "table_cap": st.table_cap, "baseline_s": st.baseline,
                "noise": st.noise, "ratio_ema": st.ratio_ema,
                "shrinks": st.shrinks, "probes": st.probes,
                "gated": st.gated,
            }
        return out
