"""Budget-aware continuous-batching scheduler over one DecodeEngine.

The paper's Sec. 6 reads N_max(eps) as a deployment knob: how many
decode positions one forward can carry near-free.  A single-request
driver spends that budget on ONE request's verification length / block
size; the scheduler spends it across MANY concurrent requests — the
"system-side parallelism selection" the NFP principle enables:

  - each request owns a SLOT (one batch row) of the engine's
    pre-allocated cache, at its own sequence length (per-slot
    ``cache_len`` threading through the decode forward),
  - admission keeps the active set small enough that every request gets
    at least one position inside the budget; the rest queue.  Newly
    admitted requests are prefilled TOGETHER: prompts are padded to a
    power-of-two length bucket and all new slots fill in one forward
    (one XLA compile per bucket instead of one per distinct prompt
    length — see ``DecodeEngine.prefill_slots``),
  - every scheduler step the ALGORITHM ADAPTER drives one (or, for
    diffusion refinement, a few) batched multi-position forwards whose
    total positions (active slots x per-request width) never exceed
    N_max(eps).

All four parallel-decoding families run through the same ``SlotAdapter``
propose → verify → commit protocol (``serving.algorithm``):

  greedy       1 position per request per forward (lossless, minimal
               latency variance),
  speculative  per-request n-gram verification windows sized so the
               whole forward stays inside the budget (ASPD-style
               adaptive splitting; lossless),
  mtp          per-request head-bank proposals from each row's real
               last hidden state, one shared verify forward (lossless),
  diffusion    per-request mask-block refinement where every refinement
               iteration is one shared forward and a final shared
               forward commits clean KV (matches the solo driver's
               token stream per request).

Greedy/speculative/mtp streams are identical to running each request
alone through ``DecodeEngine.greedy_generate``; diffusion streams are
identical to the solo ``DiffusionBlockDecoder`` at the same block size.

Load-pressure policies (``repro.loadgen`` drives them under traced
traffic):

  admission control   ``submit`` applies per-loop backpressure (bounded
                      waiting queue -> ``AdmissionRejected``), and
                      ``admit`` drains the queue in SLO-class priority
                      order rather than raw FIFO (FIFO within a class).
  preemption          ``preempt(slot)`` evicts an active request's KV
                      (paged blocks return to the pool) and requeues it;
                      re-admission RECOMPUTES the evicted KV by
                      prefilling ``req.context`` — the already-emitted
                      stream and the pending token are host state, so a
                      preempted request resumes byte-identically to a
                      never-preempted run (tests/test_loadgen.py
                      goldens).  With ``AdmissionConfig.preemption`` a
                      higher-priority arrival preempts the
                      lowest-priority active victim when the pool or
                      slot supply blocks its admission.

Admission is ARRIVAL-driven, not step-driven: ``step`` only decodes
(the hot path ``repro.analysis`` walks), while ``run`` and the trace
harness call ``admit`` at the arrival boundary — where prompt upload
and first-token readback are inherent, one batched transfer per
admission group.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.ops import slack_report
from repro.serving.algorithm import SlotAdapter
from repro.serving.diffusion import DiffusionSlotAdapter
from repro.serving.engine import DecodeEngine, greedy_tokens
from repro.serving.mtp import MTPSlotAdapter
from repro.serving.speculative import SpeculativeSlotAdapter

__all__ = ["AdmissionConfig", "AdmissionRejected", "Request", "SLOClass",
           "ServingLoop", "DEFAULT_SLO_CLASSES"]

Array = jax.Array


@dataclass(frozen=True)
class SLOClass:
    """One multi-tenant service class: admission priority plus the
    latency targets ``repro.loadgen.stats`` scores goodput against."""

    name: str
    priority: int = 0                  # higher admits first, preempts lower
    ttft_target_s: float = float("inf")
    itl_target_s: float = float("inf")


#: interactive beats default beats batch; targets are TPU-scale virtual
#: seconds (the load harness measures against the simulated clock)
DEFAULT_SLO_CLASSES: Dict[str, SLOClass] = {
    "interactive": SLOClass("interactive", priority=10,
                            ttft_target_s=0.5, itl_target_s=0.05),
    "default": SLOClass("default", priority=0,
                        ttft_target_s=2.0, itl_target_s=0.2),
    "batch": SLOClass("batch", priority=-10),
}


class AdmissionRejected(RuntimeError):
    """Backpressure: the waiting queue is at ``max_waiting`` capacity."""


@dataclass(frozen=True)
class AdmissionConfig:
    """Admission-control policy knobs (all default to the legacy
    behavior: unbounded FIFO queue, no preemption, one class).

    ``max_waiting``  bounds the waiting queue; ``submit`` beyond it
                     raises ``AdmissionRejected`` (backpressure —
                     callers shed load instead of growing an unbounded
                     queue whose tail can never meet its SLO).
    ``preemption``   lets ``admit`` evict the lowest-priority active
                     request when a STRICTLY higher-priority arrival
                     cannot get a slot or enough KV blocks.  A victim
                     re-enters the queue at its own (lower) priority,
                     so it can never preempt back: no thrash cycles.
    ``classes``      the SLO-class registry ``submit`` validates
                     against (None -> ``DEFAULT_SLO_CLASSES``).
    """

    max_waiting: Optional[int] = None
    preemption: bool = False
    classes: Optional[Dict[str, SLOClass]] = None

    def slo(self, name: str) -> SLOClass:
        table = self.classes if self.classes is not None \
            else DEFAULT_SLO_CLASSES
        return table[name]


@dataclass
class Request:
    """One generation request and its runtime state."""

    rid: int
    prompt: np.ndarray                     # (p,) int64
    max_tokens: int
    generated: List[int] = field(default_factory=list)
    pending: Optional[int] = None          # next token to feed (emitted,
    slot: Optional[int] = None             #   not yet in the cache)
    hidden: Optional[Array] = None         # (d,) state MTP proposes from
    done: bool = False
    slo_class: str = "default"
    preemptions: int = 0                   # times evicted + requeued

    @property
    def context(self) -> np.ndarray:
        """Tokens whose KV is committed in the request's cache slot."""
        n_cached = len(self.generated) - 1      # all but the pending token
        return np.concatenate(
            [self.prompt, self.generated[:n_cached]]).astype(np.int64)

    def tokens(self) -> np.ndarray:
        return np.asarray(self.generated[:self.max_tokens], np.int64)


class ServingLoop:
    """Multiplex concurrent requests through one shared DecodeEngine.

    ``mode`` selects the per-slot algorithm adapter (see module
    docstring); a custom ``SlotAdapter`` subclass instance can be
    plugged in directly via ``adapter=`` (it receives this loop).
    ``mtp_heads`` feeds the mtp adapter; ``block_size`` /
    ``refine_steps`` / ``mask_id`` feed the diffusion adapter.

    ``controller`` (an ``autotune.BudgetController``) replaces the raw
    analytic ``engine.nfp_budget`` as the per-step position budget: the
    analytic value stays the hard cap, but the controller shrinks and
    probes inside it against the step latency the loop actually
    observes (admission keeps the analytic gate — concurrency is a
    throughput decision, the controller governs per-forward width).
    ``step_clock(width, ell) -> seconds`` substitutes a latency model
    for the wall clock (one call per forward of that step) — the
    calibration benchmark injects the roofline simulator here, since a
    CPU host cannot time the TPU-target forward it is scheduling.
    """

    MODES = ("greedy", "speculative", "diffusion", "mtp")

    def __init__(self, engine: DecodeEngine, mode: str = "greedy",
                 eps: float = 0.2, max_width: int = 16,
                 adapter: Optional[SlotAdapter] = None,
                 mtp_heads: Optional[Dict] = None,
                 block_size: Optional[int] = None, refine_steps: int = 4,
                 mask_id: Optional[int] = None,
                 controller=None,
                 step_clock: Optional[Callable[[int, int], float]] = None,
                 admission: Optional[AdmissionConfig] = None):
        self.engine = engine
        self.eps = eps
        self.max_width = max_width
        self.controller = controller
        self.step_clock = step_clock
        self.admission = admission if admission is not None \
            else AdmissionConfig()
        if adapter is None:
            if mode not in self.MODES:
                raise ValueError(f"unknown serving mode {mode!r}")
            if mode == "greedy":
                adapter = SlotAdapter(self)
            elif mode == "speculative":
                adapter = SpeculativeSlotAdapter(self)
            elif mode == "mtp":
                adapter = MTPSlotAdapter(self, mtp_heads)
            else:
                adapter = DiffusionSlotAdapter(
                    self, block_size=block_size, refine_steps=refine_steps,
                    mask_id=mask_id)
        self.adapter = adapter
        self.mode = adapter.mode
        if controller is not None:
            controller.bind(self.mode, engine.use_kernel,
                            clocked=step_clock is not None)
        # budget provenance of the CURRENT step (set by ``budget()``,
        # read by ``shared_forward`` telemetry and ``step`` timing)
        self._budget_info: Dict = {}
        self.waiting: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}            # slot -> request
        self.free_slots: List[int] = list(range(engine.batch))
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        # load-pressure telemetry (preemption / backpressure policies)
        self.preempted_total = 0
        self.resumed_total = 0
        self.rejected_total = 0
        # engine.prefill_log outlives this loop — remember where ours starts
        self._prefill_log_start = len(engine.prefill_log)
        # per-forward telemetry: active/width/positions/budget plus, when
        # serving through the kernel path, its measured granularity slack
        # (attn_row_util, kv_tiles_executed/grid/skipped, kv_tile_util) —
        # the measured counterpart of the core.nfp M_attn prediction.
        # Diffusion logs one entry per refinement/commit forward, so
        # ``len(step_log)`` counts FORWARDS in every mode.
        self.step_log: List[Dict] = []

    # ------------------------------------------------------------------
    def submit(self, prompt, max_tokens: int,
               slo_class: str = "default") -> Request:
        try:
            self.admission.slo(slo_class)
        except KeyError:
            raise ValueError(f"unknown SLO class {slo_class!r}") from None
        cap = self.admission.max_waiting
        if cap is not None and len(self.waiting) >= cap:
            self.rejected_total += 1
            raise AdmissionRejected(
                f"waiting queue at capacity ({cap}); shed load or retry")
        prompt = np.asarray(prompt, np.int64).ravel()
        # reject here, where the caller can handle it per-request — an
        # admission-time failure would abort every in-flight request.
        # The prompt-alone check matters: ``prefill_bucket`` clamps its
        # bucket to max_len, so an oversized prompt used to fail deep in
        # the prefill machinery (or silently truncate on some paths)
        # instead of at the API surface.
        headroom = self.adapter.headroom()
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if len(prompt) > self.engine.max_len:
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds the engine's "
                f"max_len={self.engine.max_len}; it can never be admitted")
        if len(prompt) + int(max_tokens) + headroom > self.engine.max_len:
            raise ValueError(
                f"request of {len(prompt)} prompt + {max_tokens} tokens "
                f"(+{headroom} draft headroom) cannot fit "
                f"max_len={self.engine.max_len}")
        mgr = self.engine.manager
        if mgr is not None:
            worst = -(-min(len(prompt) + int(max_tokens) + headroom,
                           self.engine.max_len) // mgr.block_size)
            if worst > mgr.n_blocks:
                raise ValueError(
                    f"request needs up to {worst} KV blocks but the pool "
                    f"only has {mgr.n_blocks}; it can never be admitted")
        req = Request(self._next_rid, prompt, int(max_tokens),
                      slo_class=slo_class)
        self._next_rid += 1
        self.waiting.append(req)
        return req

    # ------------------------------------------------------------------
    def budget(self) -> int:
        """Position budget at the CURRENT longest active context:
        the analytic NFP budget, refined by the ``BudgetController``
        when one is attached (predicted / calibrated / applied
        provenance lands in each forward's ``step_log`` entry).

        Reads the engine's HOST mirror of the slot lengths — budgeting
        must never block on a device read in the decode hot path."""
        lens = self.engine.slot_lens_host
        ell = max(int(lens.max()) if lens.size else 1, 1)
        analytic = self.engine.nfp_budget(self.eps, ell=ell)
        info = {"ell": ell, "analytic": analytic, "applied": analytic}
        if self.controller is not None:
            info["applied"] = self.controller.budget(
                ell, len(self.active), analytic)
            calibrated = self.controller.table_budget(
                ell, len(self.active), analytic)
            if calibrated is not None:
                info["calibrated"] = calibrated
        self._budget_info = info
        return info["applied"]

    def _reserve_len(self, req: Request) -> int:
        """Cache positions a request can touch over its lifetime."""
        return min(len(req.prompt) + req.max_tokens
                   + self.adapter.headroom(), self.engine.max_len)

    @staticmethod
    def _admit_tokens(req: Request) -> np.ndarray:
        """Positions a (re-)admission must have committed KV for.  Fresh
        requests prefill their prompt; a preempted request RECOMPUTES
        its evicted cache by prefilling ``context`` (prompt + generated
        minus the pending token) — the stream itself is host state, so
        nothing re-emits and the resumed request is indistinguishable
        from one that was never evicted."""
        return req.context if req.generated else req.prompt

    def _priority(self, req: Request) -> int:
        return self.admission.slo(req.slo_class).priority

    def _pop_candidate(self) -> Optional[Request]:
        """Highest-priority waiting request (FIFO within a class: rid
        order — a preempted request keeps its original rid, so it
        resumes ahead of later arrivals of its own class)."""
        if not self.waiting:
            return None
        best = min(self.waiting, key=lambda r: (-self._priority(r), r.rid))
        self.waiting.remove(best)
        return best

    def _block_cost(self, req: Request) -> int:
        """Pool blocks this admission consumes: fresh allocations PLUS
        the evictable cached blocks it would pin (they stop being
        reclaimable), per ``BlockManager.admission_cost``."""
        mgr = self.engine.manager
        if mgr is None:
            return 0
        need, pinned = mgr.admission_cost(
            self._admit_tokens(req).tolist(), self._reserve_len(req))
        return need + pinned

    def _blocks_left(self, promised: int) -> int:
        """Free + evictable blocks minus what THIS admission group has
        already promised to candidates not yet prefilled."""
        mgr = self.engine.manager
        return (mgr.available_blocks() - promised) if mgr is not None else 0

    def _fits(self, req: Request, promised: int) -> bool:
        if not self.free_slots:
            return False
        if self.engine.manager is None:
            return True
        return self._block_cost(req) <= self._blocks_left(promised)

    def preempt(self, slot: int) -> Request:
        """Evict the request in ``slot`` mid-stream: its paged blocks
        return to the pool (dense: the row's length zeroes) and it
        re-enters the waiting queue for recompute-on-resume.  MTP
        proposal state is rebuilt from the resume prefill's last hidden,
        so no device state survives the eviction."""
        req = self.active.pop(slot)
        self.engine.preempt_slot(slot)
        self.free_slots.append(slot)
        req.slot = None
        req.hidden = None
        req.preemptions += 1
        self.preempted_total += 1
        self.waiting.appendleft(req)
        return req

    def _preempt_for(self, cand: Request, promised: int) -> None:
        """Priority preemption: while ``cand`` (already popped from the
        queue) cannot get a slot or enough KV blocks, evict the
        lowest-priority active request — only ever one with priority
        STRICTLY below the candidate's, so victims (which requeue at
        their own priority) can never preempt back."""
        while not self._fits(cand, promised):
            victims = [s for s, r in self.active.items()
                       if self._priority(r) < self._priority(cand)]
            if not victims:
                return
            # lowest priority first; latest-admitted (largest rid) tie-
            # break wastes the least completed work
            victim = max(victims, key=lambda s: (
                -self._priority(self.active[s]), self.active[s].rid))
            self.preempt(victim)

    def admit(self) -> int:
        """Admission: fill free slots in SLO-priority order while every
        active request still fits >= 1 position inside the budget, then
        prefill ALL newly admitted slots in one bucketed batched
        forward.  Returns the number of requests admitted.

        On a paged engine the gate is FREE BLOCKS, not free slots alone:
        a candidate only admits if the pool can cover its whole
        reservation (prompt + max_tokens + headroom, minus whatever its
        prefix-cache hit reuses) — evictable cache-only blocks count as
        available.  Requests that don't fit yet simply wait; retirement
        and LRU eviction free blocks over time (and, under
        ``AdmissionConfig.preemption``, a higher-priority candidate
        evicts the lowest-priority active request instead of waiting).

        Called from ``run`` and the trace harness at the ARRIVAL
        boundary, never from ``step``: admission is where prompts enter
        and first tokens leave, so its device<->host traffic is
        inherent — and batched: one ``greedy_tokens`` readback covers
        every freshly admitted slot (resumed requests need none, their
        pending token is host state already)."""
        admitted: Dict[int, Request] = {}
        promised = 0                      # blocks owed to this group
        ell = int(self.engine.slot_lens_host.max())
        while self.free_slots or self.admission.preemption:
            cand = self._pop_candidate()
            if cand is None:
                break
            if self.admission.preemption:
                self._preempt_for(cand, promised)
            # prospective budget once the candidate's context lands
            ell_next = max(ell, len(self._admit_tokens(cand)), 1)
            budget = self.engine.nfp_budget(self.eps, ell=ell_next)
            over_budget = (len(self.active) + len(admitted)
                           >= max(1, budget))
            if over_budget or not self._fits(cand, promised):
                # head-of-line within priority order: don't skip ahead,
                # retirement/eviction frees blocks over time
                self.waiting.appendleft(cand)
                break
            promised += self._block_cost(cand)
            slot = self.free_slots.pop(0)
            cand.slot = slot
            admitted[slot] = cand
            ell = ell_next
        if not admitted:
            return 0
        outs = self.engine.prefill_slots(
            {s: self._admit_tokens(r) for s, r in admitted.items()},
            reserve={s: self._reserve_len(r) for s, r in admitted.items()})
        fresh = sorted(s for s, r in admitted.items() if not r.generated)
        if fresh:
            # first token of every fresh request in ONE device argmax +
            # one small (k,) readback — the admission-boundary transfer
            first = np.asarray(greedy_tokens(
                jnp.stack([outs[s][0] for s in fresh])))
            for i, s in enumerate(fresh):
                req = admitted[s]
                req.pending = int(first[i])
                req.generated = [req.pending]
        for slot, req in admitted.items():
            if req.preemptions and slot not in fresh:
                self.resumed_total += 1
            self.active[slot] = req
            self.adapter.begin(req, outs[slot][1])
        return len(admitted)

    # ------------------------------------------------------------------
    def _attn_slack(self, width: int) -> Optional[Dict]:
        """Model this forward's kernel-granularity slack: the ragged decode
        kernel's physical query rows / kv tiles vs the useful work of the
        active slots (``ops.slack_report`` mirrors the kernel's per-row
        tile-skip rule exactly).  None when the engine runs the XLA
        reference path (nothing is tiled, so reporting tile slack would
        fabricate a measurement) or for archs the kernel doesn't serve
        (MLA / attention-free)."""
        a = self.engine.cfg.attention
        if not self.engine.use_kernel or a is None or a.kind == "mla":
            return None
        active = np.zeros(self.engine.batch, bool)
        active[list(self.active)] = True
        extra = {}
        if self.engine.manager is not None:
            # the paged launch tiles kv by PAGE: its k_block is the kv
            # block size, so executed/grid tiles stay honest under paging
            extra["k_block"] = self.engine.manager.block_size
        return slack_report(
            width, self.engine.slot_lens_host, self.engine.max_len,
            head_dim=a.head_dim,
            window=a.window if a.kind == "swa" else None,
            active=active, **extra)

    def shared_forward(self, tokens: np.ndarray, budget: int
                       ) -> Tuple[Array, Dict, Array]:
        """ONE batched multi-position decode forward over all slots,
        WITHOUT committing; appends this forward's telemetry entry.
        Returns (logits, new_cache, hidden)."""
        width = tokens.shape[1]
        entry = {
            "active": len(self.active), "width": width,
            "positions": len(self.active) * width, "budget": budget,
            "budget_analytic": self._budget_info.get("analytic", budget),
            "ell": self._budget_info.get("ell", 1),
        }
        if "calibrated" in self._budget_info:
            entry["budget_calibrated"] = self._budget_info["calibrated"]
        if self.engine.manager is not None:
            entry["kv_blocks_used"] = self.engine.manager.blocks_used()
        slack = self._attn_slack(width)
        if slack is not None:
            entry.update({
                "attn_rows_physical": slack["rows_physical"],
                "attn_row_util": slack["row_utilization"],
                "kv_tiles_executed": slack["kv_tiles_executed"],
                "kv_tiles_grid": slack["kv_tiles_grid"],
                "kv_tiles_skipped": slack["kv_tiles_skipped"],
                "kv_tile_util": slack["kv_tile_utilization"],
            })
        self.step_log.append(entry)
        return self.engine.decode_slots(jnp.asarray(tokens, jnp.int32))

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One DECODE iteration: let the adapter drive its shared
        forward(s) + per-slot commit, retire finished requests.  Returns
        False when no work remains.  Admission is the caller's move
        (``run`` / the trace harness invoke ``admit`` at the arrival
        boundary) — this keeps the steady-state decode path free of the
        prompt-upload/first-token transfers admission inherently makes
        (``repro.analysis`` walks exactly this function)."""
        if not self.active:
            return bool(self.waiting)
        budget = self.budget()
        slots = sorted(self.active)
        width = self.adapter.width(len(slots), budget)
        mark = len(self.step_log)
        t0 = time.perf_counter()
        self.adapter.run_step(slots, width, budget)
        # --- step latency + controller feedback ------------------------
        # run_step host-syncs on its accept loop, so the wall clock is a
        # faithful per-step latency on a real accelerator; step_clock
        # substitutes a latency model per forward (benchmarks on CPU).
        dt = time.perf_counter() - t0
        new = self.step_log[mark:]
        if new:
            if self.step_clock is not None:
                ell = self._budget_info.get("ell", 1)
                dt = sum(self.step_clock(e["width"], ell) for e in new)
            new[-1]["step_latency_s"] = dt
            if self.controller is not None:
                ratio = self.controller.observe(
                    self._budget_info.get("ell", 1),
                    max(e["width"] for e in new), dt / len(new))
                if ratio is not None:
                    new[-1]["latency_ratio"] = ratio
        # --- retire ----------------------------------------------------
        for s in slots:
            req = self.active[s]
            if len(req.generated) >= req.max_tokens:
                req.done = True
                self.finished[req.rid] = req
                del self.active[s]
                self.engine.release_slot(s)
                self.free_slots.append(s)
        return bool(self.active or self.waiting)

    # ------------------------------------------------------------------
    def run(self) -> Dict[int, np.ndarray]:
        """Serve until the queue drains; returns {rid: tokens}."""
        while True:
            self.admit()
            if not self.active and self.waiting:
                raise RuntimeError(
                    "admission stalled with an empty active set — the "
                    "pool cannot cover the head-of-queue reservation "
                    "(submit() should have rejected it)")
            if not self.step():
                break
        return {rid: req.tokens() for rid, req in
                sorted(self.finished.items())}

    # ------------------------------------------------------------------
    def stats(self) -> Dict:
        total_tokens = sum(len(r.tokens()) for r in self.finished.values())
        total_positions = sum(e["positions"] for e in self.step_log)
        forwards = len(self.step_log)
        out = {
            "requests": len(self.finished),
            "tokens": total_tokens,
            "forwards": forwards,
            "preemptions": self.preempted_total,
            "resumes": self.resumed_total,
            "rejections": self.rejected_total,
            "positions": total_positions,
            "tokens_per_forward": total_tokens / max(forwards, 1),
            "position_utilization": total_tokens / max(total_positions, 1),
            "max_positions_per_forward": max(
                (e["positions"] for e in self.step_log), default=0),
        }
        prefills = self.engine.prefill_log[self._prefill_log_start:]
        out["prefill_forwards"] = len(prefills)
        out["prefill_buckets"] = sorted({e["bucket"] for e in prefills})
        out["prefill_positions_computed"] = sum(
            e.get("computed_tokens", 0) for e in prefills)
        if self.engine.manager is not None:
            # paged-cache accounting: pool occupancy plus the prefix-hit
            # counters — ``prefill_positions_saved`` is the prompt
            # positions admissions did NOT have to prefill
            out.update(self.engine.manager.stats())
            out["prefill_positions_saved"] = sum(
                e.get("cached_tokens", 0) for e in prefills)
        # budget provenance: what the analytic predictor said, what the
        # calibration table said, what was actually spent — plus the
        # controller's observed-latency accounting when one is attached
        if self.step_log:
            out["mean_budget"] = (sum(e["budget"] for e in self.step_log)
                                  / len(self.step_log))
            out["mean_budget_analytic"] = (
                sum(e.get("budget_analytic", e["budget"])
                    for e in self.step_log) / len(self.step_log))
            calibrated = [e["budget_calibrated"] for e in self.step_log
                          if "budget_calibrated" in e]
            if calibrated:
                out["mean_budget_calibrated"] = (sum(calibrated)
                                                 / len(calibrated))
        latencies = [e["step_latency_s"] for e in self.step_log
                     if "step_latency_s" in e]
        if latencies:
            out["step_latency_total_s"] = sum(latencies)
        ratios = [e["latency_ratio"] for e in self.step_log
                  if "latency_ratio" in e]
        if ratios:
            out["mean_latency_ratio"] = sum(ratios) / len(ratios)
            out["max_latency_ratio"] = max(ratios)
        if self.controller is not None:
            out["controller"] = self.controller.stats()
        slacked = [e for e in self.step_log if "kv_tile_util" in e]
        if slacked:
            out["mean_attn_row_util"] = (
                sum(e["attn_row_util"] for e in slacked) / len(slacked))
            out["mean_kv_tile_util"] = (
                sum(e["kv_tile_util"] for e in slacked) / len(slacked))
            out["kv_tiles_skipped"] = sum(
                e["kv_tiles_skipped"] for e in slacked)
            out["kv_tiles_executed"] = sum(
                e["kv_tiles_executed"] for e in slacked)
        return out
