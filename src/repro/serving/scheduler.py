"""Budget-aware continuous-batching scheduler over one DecodeEngine.

The paper's Sec. 6 reads N_max(eps) as a deployment knob: how many
decode positions one forward can carry near-free.  A single-request
driver spends that budget on ONE request's verification length / block
size; the scheduler spends it across MANY concurrent requests — the
"system-side parallelism selection" the NFP principle enables:

  - each request owns a SLOT (one batch row) of the engine's
    pre-allocated cache, at its own sequence length (per-slot
    ``cache_len`` threading through the decode forward),
  - admission keeps the active set small enough that every request gets
    at least one position inside the budget; the rest queue,
  - every scheduler step runs ONE batched multi-position forward whose
    total positions (active slots x per-request width) never exceed
    N_max(eps): in ``greedy`` mode width is 1 and the budget caps
    concurrency; in ``speculative`` mode the remaining budget is split
    evenly into per-request n-gram verification windows (ASPD-style
    adaptive splitting), so a lone request gets the whole budget and a
    full house degrades gracefully to width 1.

Greedy acceptance everywhere: every request's token stream is identical
to running it alone through ``DecodeEngine.greedy_generate``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.ops import slack_report
from repro.serving.engine import DecodeEngine
from repro.serving.speculative import ngram_draft

__all__ = ["Request", "ServingLoop"]


@dataclass
class Request:
    """One generation request and its runtime state."""

    rid: int
    prompt: np.ndarray                     # (p,) int64
    max_tokens: int
    generated: List[int] = field(default_factory=list)
    pending: Optional[int] = None          # next token to feed (emitted,
    slot: Optional[int] = None             #   not yet in the cache)
    done: bool = False

    @property
    def context(self) -> np.ndarray:
        """Tokens whose KV is committed in the request's cache slot."""
        n_cached = len(self.generated) - 1      # all but the pending token
        return np.concatenate(
            [self.prompt, self.generated[:n_cached]]).astype(np.int64)

    def tokens(self) -> np.ndarray:
        return np.asarray(self.generated[:self.max_tokens], np.int64)


class ServingLoop:
    """Multiplex concurrent requests through one shared DecodeEngine.

    The engine's batch dimension is the slot pool.  ``mode``:
      greedy       1 position per request per forward (lossless,
                   minimal latency variance),
      speculative  per-request n-gram drafts sized so the whole forward
                   stays inside the NFP budget (lossless, higher
                   throughput when the context has structure).
    """

    def __init__(self, engine: DecodeEngine, mode: str = "greedy",
                 eps: float = 0.2, max_width: int = 16):
        if mode not in ("greedy", "speculative"):
            raise ValueError(f"unknown serving mode {mode!r}")
        self.engine = engine
        self.mode = mode
        self.eps = eps
        self.max_width = max_width
        self.waiting: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}            # slot -> request
        self.free_slots: List[int] = list(range(engine.batch))
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        # per-step telemetry: active/width/positions/budget plus, when
        # serving through the kernel path, its measured granularity slack
        # (attn_row_util, kv_tiles_executed/grid/skipped, kv_tile_util) —
        # the measured counterpart of the core.nfp M_attn prediction
        self.step_log: List[Dict] = []

    # ------------------------------------------------------------------
    def submit(self, prompt, max_tokens: int) -> Request:
        prompt = np.asarray(prompt, np.int64).ravel()
        # reject here, where the caller can handle it per-request — an
        # admission-time failure would abort every in-flight request.
        # Speculative forwards run the uniform width over every row, so
        # a nearly-done row still needs draft headroom in its buffer.
        headroom = 0 if self.mode == "greedy" else self.max_width
        if len(prompt) + int(max_tokens) + headroom > self.engine.max_len:
            raise ValueError(
                f"request of {len(prompt)} prompt + {max_tokens} tokens "
                f"(+{headroom} draft headroom) cannot fit "
                f"max_len={self.engine.max_len}")
        req = Request(self._next_rid, prompt, int(max_tokens))
        self._next_rid += 1
        self.waiting.append(req)
        return req

    # ------------------------------------------------------------------
    def budget(self) -> int:
        """NFP budget at the CURRENT longest active context."""
        lens = np.asarray(self.engine.slot_lens)
        ell = int(lens.max()) if lens.size else 1
        return self.engine.nfp_budget(self.eps, ell=ell)

    def _admit(self) -> None:
        """Admission: fill free slots while every active request still
        fits >= 1 position inside the budget."""
        while (self.waiting and self.free_slots
               and len(self.active) < max(1, self.budget())):
            req = self.waiting.popleft()
            slot = self.free_slots.pop(0)
            logits = self.engine.prefill_slot(slot, req.prompt)
            req.pending = int(jnp.argmax(logits))
            req.generated = [req.pending]
            req.slot = slot
            self.active[slot] = req

    def _widths(self, n_active: int, budget: int) -> int:
        """Split the position budget evenly across active requests."""
        if self.mode == "greedy":
            return 1
        w = max(1, budget // max(n_active, 1))
        return min(w, self.max_width)

    def _attn_slack(self, width: int) -> Optional[Dict]:
        """Model this forward's kernel-granularity slack: the ragged decode
        kernel's physical query rows / kv tiles vs the useful work of the
        active slots (``ops.slack_report`` mirrors the kernel's per-row
        tile-skip rule exactly).  None when the engine runs the XLA
        reference path (nothing is tiled, so reporting tile slack would
        fabricate a measurement) or for archs the kernel doesn't serve
        (MLA / attention-free)."""
        a = self.engine.cfg.attention
        if not self.engine.use_kernel or a is None or a.kind == "mla":
            return None
        active = np.zeros(self.engine.batch, bool)
        active[list(self.active)] = True
        return slack_report(
            width, np.asarray(self.engine.slot_lens), self.engine.max_len,
            head_dim=a.head_dim,
            window=a.window if a.kind == "swa" else None,
            active=active)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One scheduler iteration: admit, one batched forward, per-slot
        accept/commit, retire finished requests.  Returns False when no
        work remains."""
        self._admit()
        if not self.active:
            return bool(self.waiting)
        eng = self.engine
        budget = self.budget()
        width = self._widths(len(self.active), budget)
        slots = sorted(self.active)
        # --- build the (batch, width) token block ----------------------
        tokens = np.zeros((eng.batch, width), np.int64)
        drafts: Dict[int, np.ndarray] = {}
        for s in slots:
            req = self.active[s]
            tokens[s, 0] = req.pending
            # clip each row's drafts to its remaining tokens — budget
            # positions past a request's max_tokens would be discarded
            n_draft = min(width - 1,
                          req.max_tokens - len(req.generated) - 1)
            if n_draft > 0:
                d = ngram_draft(np.append(req.context, req.pending),
                                n_draft, vocab_size=eng.cfg.vocab_size)
                drafts[s] = d
                tokens[s, 1:1 + n_draft] = d
        entry = {
            "active": len(self.active), "width": width,
            "positions": len(self.active) * width, "budget": budget,
        }
        slack = self._attn_slack(width)
        if slack is not None:
            entry.update({
                "attn_rows_physical": slack["rows_physical"],
                "attn_row_util": slack["row_utilization"],
                "kv_tiles_executed": slack["kv_tiles_executed"],
                "kv_tiles_grid": slack["kv_tiles_grid"],
                "kv_tiles_skipped": slack["kv_tiles_skipped"],
                "kv_tile_util": slack["kv_tile_utilization"],
            })
        self.step_log.append(entry)
        # --- one shared multi-position forward -------------------------
        logits, new_cache = eng.decode_slots(jnp.asarray(tokens, jnp.int32))
        preds = np.asarray(jnp.argmax(logits, axis=-1))     # (batch, width)
        # --- per-slot greedy acceptance + commit -----------------------
        advances = np.zeros((eng.batch,), np.int32)
        for s in slots:
            req = self.active[s]
            k = 0
            d = drafts.get(s)
            if d is not None:
                while k < len(d) and preds[s, k] == d[k]:
                    k += 1
                req.generated.extend(int(t) for t in d[:k])
            bonus = int(preds[s, k])
            req.generated.append(bonus)
            advances[s] = 1 + k                  # pending + accepted drafts
            req.pending = bonus
        eng.commit_slots(new_cache, advances)
        # --- retire ----------------------------------------------------
        for s in slots:
            req = self.active[s]
            if len(req.generated) >= req.max_tokens:
                req.done = True
                self.finished[req.rid] = req
                del self.active[s]
                eng.release_slot(s)
                self.free_slots.append(s)
        return bool(self.active or self.waiting)

    # ------------------------------------------------------------------
    def run(self) -> Dict[int, np.ndarray]:
        """Serve until the queue drains; returns {rid: tokens}."""
        while self.step():
            pass
        return {rid: req.tokens() for rid, req in
                sorted(self.finished.items())}

    # ------------------------------------------------------------------
    def stats(self) -> Dict:
        total_tokens = sum(len(r.tokens()) for r in self.finished.values())
        total_positions = sum(e["positions"] for e in self.step_log)
        forwards = len(self.step_log)
        out = {
            "requests": len(self.finished),
            "tokens": total_tokens,
            "forwards": forwards,
            "positions": total_positions,
            "tokens_per_forward": total_tokens / max(forwards, 1),
            "position_utilization": total_tokens / max(total_positions, 1),
            "max_positions_per_forward": max(
                (e["positions"] for e in self.step_log), default=0),
        }
        slacked = [e for e in self.step_log if "kv_tile_util" in e]
        if slacked:
            out["mean_attn_row_util"] = (
                sum(e["attn_row_util"] for e in slacked) / len(slacked))
            out["mean_kv_tile_util"] = (
                sum(e["kv_tile_util"] for e in slacked) / len(slacked))
            out["kv_tiles_skipped"] = sum(
                e["kv_tiles_skipped"] for e in slacked)
            out["kv_tiles_executed"] = sum(
                e["kv_tiles_executed"] for e in slacked)
        return out
