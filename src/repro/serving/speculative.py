"""Speculative decoding driver — consumes the NFP position budget.

The verification forward IS a multi-position decode forward (paper
Sec. G.1: "the verification forward in speculative decoding ... shares
the same multi-position decode paradigm").  The NFP principle supplies
the system-side budget for the verification length gamma: pushing gamma
past N_max(eps) buys tokens at super-linear latency cost.

Two draft sources:
  - ngram: suffix-match lookup in the already-generated context (free),
  - draft engine: a second (smaller) DecodeEngine.
Greedy acceptance keeps the output identical to AR greedy decoding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import DecodeEngine

Array = jax.Array


def ngram_draft(context: np.ndarray, gamma: int, max_order: int = 3,
                vocab_size: int = 32000) -> np.ndarray:
    """Suffix-match n-gram draft: find the longest recent suffix that
    re-occurs earlier in the context and propose its continuation."""
    out = []
    ctx = list(context)
    for _ in range(gamma):
        prop = None
        for order in range(min(max_order, len(ctx) - 1), 0, -1):
            suffix = ctx[-order:]
            for i in range(len(ctx) - order - 1, -1, -1):
                if ctx[i:i + order] == suffix:
                    prop = ctx[i + order]
                    break
            if prop is not None:
                break
        if prop is None:
            prop = ctx[-1] if ctx else 0
        out.append(int(prop) % vocab_size)
        ctx.append(out[-1])
    return np.asarray(out, np.int64)


@dataclass
class SpeculativeDecoder:
    engine: DecodeEngine
    draft_engine: Optional[DecodeEngine] = None
    gamma: Optional[int] = None        # verification length; None -> NFP budget

    def _gamma(self) -> int:
        if self.gamma is not None:
            return self.gamma
        # NFP budget covers the whole forward: gamma drafts + 1 pending
        return max(1, self.engine.nfp_budget() - 1)

    def _propose(self, context: np.ndarray, pending: int, gamma: int
                 ) -> np.ndarray:
        if self.draft_engine is not None:
            toks = []
            last = jnp.full((self.engine.batch, 1), pending, jnp.int32)
            for _ in range(gamma):
                logits = self.draft_engine.decode_step(last)
                last = jnp.argmax(logits[:, -1], axis=-1)[:, None]
                toks.append(int(last[0, 0]))
            return np.asarray(toks, np.int64)
        return ngram_draft(np.append(context, pending), gamma,
                           vocab_size=self.engine.cfg.vocab_size)

    def generate(self, prompt: Array, max_tokens: int
                 ) -> Tuple[np.ndarray, dict]:
        """Greedy speculative generation (batch=1 driver).  Returns
        (tokens, stats) — stats includes positions/forward utilization,
        the quantity NFP normalizes (paper Sec. J.2.3)."""
        eng = self.engine
        logits = eng.prefill(prompt)
        pending = int(jnp.argmax(logits[0]))
        context = np.asarray(prompt[0])
        generated: List[int] = [pending]
        n_forwards, n_positions = 0, 0
        while len(generated) < max_tokens:
            gamma = min(self._gamma(), max_tokens - len(generated))
            drafts = self._propose(context, pending, gamma)
            block = np.concatenate([[pending], drafts]).astype(np.int64)
            toks = jnp.asarray(block[None], jnp.int32)
            toks = jnp.broadcast_to(toks, (eng.batch, toks.shape[1]))
            step_logits, new_cache = eng.peek_step(toks)
            n_forwards += 1
            n_positions += len(block)
            preds = np.asarray(jnp.argmax(step_logits[0], axis=-1))
            k = 0
            while k < gamma and preds[k] == drafts[k]:
                k += 1
            accepted = list(drafts[:k])
            bonus = int(preds[k])
            eng.commit(new_cache, 1 + k)
            if self.draft_engine is not None:
                # resync draft cache: simplest policy, re-prefill lazily
                self.draft_engine.cache_len = eng.cache_len
            context = np.concatenate([context, [pending], accepted])
            generated.extend(accepted + [bonus])
            pending = bonus
        stats = {
            "tokens": len(generated),
            "forwards": n_forwards,
            "positions": n_positions,
            "tokens_per_forward": len(generated) / max(n_forwards, 1),
            "position_utilization": len(generated) / max(n_positions, 1),
        }
        return np.asarray(generated[:max_tokens]), stats
