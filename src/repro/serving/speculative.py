"""Speculative decoding driver — consumes the NFP position budget.

The verification forward IS a multi-position decode forward (paper
Sec. G.1: "the verification forward in speculative decoding ... shares
the same multi-position decode paradigm").  The NFP principle supplies
the system-side budget for the verification length gamma: pushing gamma
past N_max(eps) buys tokens at super-linear latency cost.

Two draft sources:
  - ngram: suffix-match lookup in the already-generated context (free),
  - draft engine: a second (smaller) DecodeEngine, kept cache-coherent
    with the committed stream by rolling accepted tokens forward (the
    catch-up tokens ride in the same decode forward that starts the
    next draft, so resync costs no extra forwards).
Greedy acceptance keeps the output identical to AR greedy decoding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.algorithm import ParallelDecodeAlgorithm, SlotAdapter
from repro.serving.engine import DecodeEngine

Array = jax.Array


def ngram_draft(context: np.ndarray, gamma: int, max_order: int = 3,
                vocab_size: int = 32000) -> np.ndarray:
    """Suffix-match n-gram draft: find the longest recent suffix that
    re-occurs earlier in the context and propose its continuation."""
    out = []
    ctx = list(context)
    for _ in range(gamma):
        prop = None
        for order in range(min(max_order, len(ctx) - 1), 0, -1):
            suffix = ctx[-order:]
            for i in range(len(ctx) - order - 1, -1, -1):
                if ctx[i:i + order] == suffix:
                    prop = ctx[i + order]
                    break
            if prop is not None:
                break
        if prop is None:
            prop = ctx[-1] if ctx else 0
        out.append(int(prop) % vocab_size)
        ctx.append(out[-1])
    return np.asarray(out, np.int64)


@dataclass
class SpeculativeDecoder(ParallelDecodeAlgorithm):
    engine: DecodeEngine
    draft_engine: Optional[DecodeEngine] = None
    gamma: Optional[int] = None        # verification length; None -> NFP

    def _gamma(self) -> int:
        if self.gamma is not None:
            return self.gamma
        # NFP budget covers the whole forward: gamma drafts + 1 pending
        return max(1, self.engine.nfp_budget() - 1)

    parallel_width = _gamma

    # ------------------------------------------------------------------
    def begin(self, prompt: np.ndarray, pending: int) -> None:
        if self.draft_engine is not None:
            self.draft_engine.prefill(jnp.asarray(prompt, jnp.int32))
            # tokens whose KV the draft cache holds, in stream order
            self._draft_tokens: List[int] = [int(t) for t in prompt[0]]

    def _draft_propose(self, full: np.ndarray, gamma: int) -> np.ndarray:
        """Draft gamma tokens, first resyncing the draft KV cache.

        ``full`` is the canonical stream (committed context + pending).
        The draft cache holds KV for ``self._draft_tokens``; the shared
        prefix stays, the divergent tail (rejected drafts) is dropped by
        truncating cache_len, and the missing tokens — at minimum the
        pending token, plus any accepted-but-unseen drafts — are fed in
        ONE multi-position catch-up forward whose last logits already
        give the first draft."""
        draft = self.draft_engine
        sync = 0
        for a, b in zip(self._draft_tokens, full):
            if a != int(b):
                break
            sync += 1
        draft.cache_len = sync
        self._draft_tokens = self._draft_tokens[:sync]
        chunk = np.asarray(full[sync:], np.int64)       # >= 1: pending is new
        toks = jnp.broadcast_to(jnp.asarray(chunk[None], jnp.int32),
                                (draft.batch, len(chunk)))
        logits = draft.decode_step(toks)
        self._draft_tokens.extend(int(t) for t in chunk)
        out = []
        last = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        for _ in range(gamma):
            out.append(int(last[0, 0]))
            if len(out) == gamma:
                break
            logits = draft.decode_step(last.astype(jnp.int32))
            self._draft_tokens.append(out[-1])
            last = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return np.asarray(out, np.int64)

    def propose(self, context: np.ndarray, pending: int,
                n: int) -> np.ndarray:
        full = np.append(context, pending)
        if self.draft_engine is not None:
            return self._draft_propose(full, n)
        return ngram_draft(full, n, vocab_size=self.engine.cfg.vocab_size)


class SpeculativeSlotAdapter(SlotAdapter):
    """Scheduler-side speculative decoding: the remaining NFP budget is
    split evenly into per-request n-gram verification windows (ASPD-style
    adaptive splitting) — a lone request gets the whole budget, a full
    house degrades gracefully to width 1.  Greedy prefix acceptance per
    row keeps every stream lossless."""

    mode = "speculative"

    def width(self, n_active: int, budget: int) -> int:
        w = max(1, budget // max(n_active, 1))
        return min(w, self.loop.max_width)

    def headroom(self) -> int:
        # the shared forward runs the uniform width over every row, so a
        # nearly-done row still needs draft headroom in its cache buffer
        return self.loop.max_width

    def propose(self, req, n: int) -> np.ndarray:
        return ngram_draft(np.append(req.context, req.pending), n,
                           vocab_size=self.loop.engine.cfg.vocab_size)
