"""Multi-token prediction (MTP) driver — the third parallel-decoding
family the paper abstracts (Sec. 7.1; Gloeckle et al. 2024, DeepSeek-V3).

A bank of ``n_heads`` lightweight prediction heads (one linear head per
future offset, trained against shifted targets) proposes the next
``n_heads`` tokens from the LAST hidden state; the base model then
verifies them with ONE multi-position decode forward — identical system
structure to speculative decoding (the inherited propose -> verify ->
commit driver), but the draft is a model component rather than a
separate model, so the NFP budget directly caps the useful number of
MTP heads (paper Sec. 6: "MTP prediction length").

Greedy acceptance keeps output identical to AR greedy decoding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _init
from repro.serving.algorithm import ParallelDecodeAlgorithm
from repro.serving.engine import DecodeEngine

Array = jax.Array


def init_mtp_heads(key, d_model: int, vocab: int, n_heads: int,
                   dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, n_heads)
    return {"heads": jnp.stack([_init(k, (d_model, vocab), scale=0.02,
                                      dtype=dtype) for k in ks])}


def mtp_propose(heads: Dict, hidden: Array) -> Array:
    """hidden: (b, d) last-position hidden state -> (b, n_heads) greedy
    proposals for offsets +2..+n_heads+1."""
    logits = jnp.einsum("bd,hdv->bhv", hidden.astype(jnp.float32),
                        heads["heads"].astype(jnp.float32))
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def mtp_loss(heads: Dict, hidden: Array, tokens: Array) -> Array:
    """Train the head bank: head h predicts token at offset h+2.
    hidden: (b, s, d); tokens: (b, s)."""
    n_heads = heads["heads"].shape[0]
    total = jnp.zeros((), jnp.float32)
    for h in range(n_heads):
        off = h + 2
        if tokens.shape[1] <= off:
            break
        hs = hidden[:, :-off]
        tgt = tokens[:, off:]
        logits = (hs.astype(jnp.float32)
                  @ heads["heads"][h].astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        total = total + jnp.mean(lse - gold)
    return total / n_heads


@dataclass
class MTPDecoder(ParallelDecodeAlgorithm):
    """MTP generation: propose with the head bank, verify with one
    multi-position forward, accept greedily (lossless vs AR greedy)."""

    engine: DecodeEngine
    heads: Dict
    n_predict: Optional[int] = None      # None -> min(n_heads, NFP budget-1)

    def _n(self) -> int:
        bank = self.heads["heads"].shape[0]
        if self.n_predict is not None:
            return min(self.n_predict, bank)
        return max(1, min(bank, self.engine.nfp_budget() - 1))

    parallel_width = _n

    def propose(self, context: np.ndarray, pending: int,
                n: int) -> np.ndarray:
        # hidden state proxy: embed of pending token (heads are trained on
        # hidden states; for the driver demo the embedding row suffices)
        hid = self.engine.params["embed"]["table"][jnp.asarray([pending])]
        return np.asarray(mtp_propose(self.heads, hid))[0][:n].astype(
            np.int64)
