"""Multi-token prediction (MTP) driver — the third parallel-decoding
family the paper abstracts (Sec. 7.1; Gloeckle et al. 2024, DeepSeek-V3).

A bank of ``n_heads`` lightweight prediction heads (one linear head per
future offset, trained against shifted targets) proposes the next
``n_heads`` tokens from the LAST hidden state; the base model then
verifies them with ONE multi-position decode forward — identical system
structure to speculative decoding (the inherited propose -> verify ->
commit driver), but the draft is a model component rather than a
separate model, so the NFP budget directly caps the useful number of
MTP heads (paper Sec. 6: "MTP prediction length").

The proposal input is the REAL final-norm hidden state threaded out of
``models.transformer.forward``: the prefill hands over the last prompt
position's state, and every verify forward hands over the state at the
accepted index whose logits produced the new pending token — exactly
the state the heads were trained against (``mtp_loss``).

Greedy acceptance keeps output identical to AR greedy decoding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _init
from repro.serving.algorithm import ParallelDecodeAlgorithm, SlotAdapter
from repro.serving.engine import DecodeEngine

Array = jax.Array


def init_mtp_heads(key, d_model: int, vocab: int, n_heads: int,
                   dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, n_heads)
    return {"heads": jnp.stack([_init(k, (d_model, vocab), scale=0.02,
                                      dtype=dtype) for k in ks])}


@jax.jit
def mtp_propose(heads: Dict, hidden: Array) -> Array:
    """hidden: (b, d) last-position hidden state -> (b, n_heads) greedy
    proposals for offsets +2..+n_heads+1.

    Jitted: the head-bank einsum AND its argmax run as one device
    dispatch, so callers transfer only the (b, n_heads) i32 proposals —
    never the (b, n_heads, vocab) head logits."""
    logits = jnp.einsum("bd,hdv->bhv", hidden.astype(jnp.float32),
                        heads["heads"].astype(jnp.float32))
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def mtp_loss(heads: Dict, hidden: Array, tokens: Array) -> Array:
    """Train the head bank: head h predicts token at offset h+2.
    hidden: (b, s, d); tokens: (b, s)."""
    n_heads = heads["heads"].shape[0]
    total = jnp.zeros((), jnp.float32)
    for h in range(n_heads):
        off = h + 2
        if tokens.shape[1] <= off:
            break
        hs = hidden[:, :-off]
        tgt = tokens[:, off:]
        logits = (hs.astype(jnp.float32)
                  @ heads["heads"][h].astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        total = total + jnp.mean(lse - gold)
    return total / n_heads


@dataclass
class MTPDecoder(ParallelDecodeAlgorithm):
    """MTP generation: propose with the head bank from the real last
    hidden state, verify with one multi-position forward, accept
    greedily (lossless vs AR greedy)."""

    engine: DecodeEngine
    heads: Dict
    n_predict: Optional[int] = None      # None -> min(n_heads, NFP budget-1)

    def _n(self) -> int:
        bank = self.heads["heads"].shape[0]
        if self.n_predict is not None:
            return min(self.n_predict, bank)
        return max(1, min(bank, self.engine.nfp_budget() - 1))

    parallel_width = _n

    def begin(self, prompt: np.ndarray, pending: int) -> None:
        # the engine's prefill just produced ``pending`` from the last
        # prompt position's hidden state — propose offsets +2.. from it
        self._hidden = self.engine.last_hidden[0]

    def observe(self, hidden, k: int) -> None:
        # logits row k of the verify forward produced the new pending
        # token, so hidden row k is the state to propose from next
        self._hidden = hidden[0, k]

    def propose(self, context: np.ndarray, pending: int,
                n: int) -> np.ndarray:
        return np.asarray(mtp_propose(self.heads, self._hidden[None])
                          )[0][:n].astype(np.int64)


class MTPSlotAdapter(SlotAdapter):
    """Scheduler-side MTP: each request proposes from ITS row's last
    verify-forward hidden state (tracked on the Request across steps),
    the head bank caps the useful width, and the remaining NFP budget is
    split evenly across rows.  Greedy acceptance per row keeps every
    stream lossless."""

    mode = "mtp"

    def __init__(self, loop, heads: Dict):
        super().__init__(loop)
        if heads is None:
            raise ValueError("mtp serving mode needs an mtp_heads bank")
        self.heads = heads

    def width(self, n_active: int, budget: int) -> int:
        bank = self.heads["heads"].shape[0]
        w = max(1, budget // max(n_active, 1))
        return min(w, self.loop.max_width, bank + 1)

    def headroom(self) -> int:
        return self.loop.max_width

    def begin(self, req, hidden) -> None:
        req.hidden = hidden

    def propose(self, req, n: int) -> np.ndarray:
        return np.asarray(  # analysis: allow-host-sync — (1, heads) i32
            mtp_propose(self.heads, req.hidden[None]))[0][:n].astype(np.int64)

    def propose_rows(self, want):
        # ONE head-bank dispatch over every row's hidden state — the
        # per-row default would pay n_active device round-trips per step.
        # The transfer is the (rows, heads) i32 proposal block only.
        rows = sorted(want)
        hid = jnp.stack([self.loop.active[s].hidden for s in rows])
        props = np.asarray(  # analysis: allow-host-sync
            mtp_propose(self.heads, hid)).astype(np.int64)
        return {s: props[i][:want[s]] for i, s in enumerate(rows)}

    def observe(self, req, k: int, hidden) -> None:
        req.hidden = hidden[k]
