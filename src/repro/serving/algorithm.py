"""Common protocol for parallel-decoding algorithms.

Every family the paper abstracts — speculative verification, MTP head
verification, diffusion block refinement — is the same system-level
loop: PROPOSE a block of candidate positions, VERIFY it with one (or a
few) multi-position decode forwards (Eq. 2), COMMIT the accepted prefix
to the KV cache.  The NFP budget caps the block width in every case
(paper Sec. 6), so the driver machinery — prefill, width selection,
forward/stats accounting, context bookkeeping, commit arithmetic — is
algorithm-independent and lives here once.

A new algorithm implements ``propose`` (and optionally ``resolve`` when
verification is not single-forward greedy acceptance) and inherits the
rest; see ``speculative.py`` / ``mtp.py`` / ``diffusion.py`` for the
three ~50-line instantiations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import DecodeEngine

__all__ = ["DecodeStats", "ParallelDecodeAlgorithm"]


@dataclass
class DecodeStats:
    """Position/forward accounting — the quantities NFP normalizes
    (paper Sec. J.2.3)."""

    tokens: int = 0
    forwards: int = 0
    positions: int = 0

    @property
    def tokens_per_forward(self) -> float:
        return self.tokens / max(self.forwards, 1)

    @property
    def position_utilization(self) -> float:
        return self.tokens / max(self.positions, 1)

    def as_dict(self) -> Dict:
        return {
            "tokens": self.tokens,
            "forwards": self.forwards,
            "positions": self.positions,
            "tokens_per_forward": self.tokens_per_forward,
            "position_utilization": self.position_utilization,
        }


@dataclass
class ParallelDecodeAlgorithm:
    """Propose -> verify -> commit driver over one DecodeEngine.

    Subclass protocol:
      parallel_width()        block width for the next step; the default
                              spends the engine's NFP budget (reserving
                              one position for the pending token).
      propose(ctx, pending, n) length-n candidate block (np.int64).
      resolve(pending, drafts) verify + commit; returns (committed
                              tokens — now in the cache after
                              ``pending`` — and the next pending token).
                              Default: one multi-position forward with
                              greedy prefix acceptance, which keeps the
                              output stream identical to AR greedy.
      begin(prompt, pending)  optional hook after target prefill
                              (draft-model setup and the like).
    """

    engine: DecodeEngine

    def __post_init__(self):
        self.stats = DecodeStats()

    # ------------------------------------------------------------------
    # protocol (overridable)
    # ------------------------------------------------------------------
    def parallel_width(self) -> int:
        return max(1, self.engine.nfp_budget() - 1)

    def begin(self, prompt: np.ndarray, pending: int) -> None:
        pass

    def propose(self, context: np.ndarray, pending: int,
                n: int) -> np.ndarray:
        raise NotImplementedError

    def resolve(self, pending: int, drafts: np.ndarray
                ) -> Tuple[List[int], int]:
        """Greedy verification: accept the longest draft prefix the
        target model reproduces, plus the model's own next token."""
        block = np.concatenate([[pending], drafts]).astype(np.int64)
        logits, new_cache = self.forward_block(block)
        preds = np.asarray(jnp.argmax(logits[0], axis=-1))
        k = 0
        while k < len(drafts) and preds[k] == drafts[k]:
            k += 1
        self.engine.commit(new_cache, 1 + k)
        return list(drafts[:k]), int(preds[k])

    # ------------------------------------------------------------------
    # shared machinery
    # ------------------------------------------------------------------
    def forward_block(self, block: np.ndarray):
        """One multi-position decode forward over ``block`` WITHOUT
        committing; tracks forward/position stats."""
        eng = self.engine
        toks = jnp.broadcast_to(jnp.asarray(block[None], jnp.int32),
                                (eng.batch, len(block)))
        logits, new_cache = eng.peek_step(toks)
        self.stats.forwards += 1
        self.stats.positions += len(block)
        return logits, new_cache

    def generate(self, prompt, max_tokens: int
                 ) -> Tuple[np.ndarray, Dict]:
        """Greedy generation (batch=1 driver).  Returns (tokens, stats)."""
        eng = self.engine
        self.stats = DecodeStats()
        logits = eng.prefill(prompt)
        pending = int(jnp.argmax(logits[0]))
        context = np.asarray(prompt[0]).astype(np.int64)
        generated: List[int] = [pending]
        self.begin(np.asarray(prompt), pending)
        while len(generated) < max_tokens:
            n = min(self.parallel_width(), max_tokens - len(generated))
            drafts = self.propose(context, pending, n)
            committed, next_pending = self.resolve(pending, drafts)
            context = np.concatenate(
                [context, [pending], committed]).astype(np.int64)
            generated.extend(list(committed) + [next_pending])
            pending = next_pending
        self.stats.tokens = len(generated)
        return np.asarray(generated[:max_tokens]), self.stats.as_dict()
