"""Common protocol for parallel-decoding algorithms.

Every family the paper abstracts — speculative verification, MTP head
verification, diffusion block refinement — is the same system-level
loop: PROPOSE a block of candidate positions, VERIFY it with one (or a
few) multi-position decode forwards (Eq. 2), COMMIT the accepted prefix
to the KV cache.  The NFP budget caps the block width in every case
(paper Sec. 6), so the driver machinery is algorithm-independent and
lives here once, at BOTH serving granularities:

  ``ParallelDecodeAlgorithm``  the batch=1 driver: one request owns the
                               whole engine (and the whole budget).
  ``SlotAdapter``              the scheduler-side adapter: the same
                               propose → verify → commit protocol driven
                               ROW-WISE by ``ServingLoop`` — every active
                               request fills its slot's row of ONE shared
                               multi-position forward per step, and the
                               NFP budget is split across the rows.

A new algorithm implements ``propose`` (and optionally ``resolve`` /
``run_step`` when verification is not single-forward greedy acceptance,
e.g. diffusion refinement) and inherits the rest; see
``speculative.py`` / ``mtp.py`` / ``diffusion.py`` for the paired
instantiations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import DecodeEngine, greedy_tokens

__all__ = ["DecodeStats", "ParallelDecodeAlgorithm", "SlotAdapter"]


@dataclass
class DecodeStats:
    """Position/forward accounting — the quantities NFP normalizes
    (paper Sec. J.2.3)."""

    tokens: int = 0
    forwards: int = 0
    positions: int = 0

    @property
    def tokens_per_forward(self) -> float:
        return self.tokens / max(self.forwards, 1)

    @property
    def position_utilization(self) -> float:
        return self.tokens / max(self.positions, 1)

    def as_dict(self) -> Dict:
        return {
            "tokens": self.tokens,
            "forwards": self.forwards,
            "positions": self.positions,
            "tokens_per_forward": self.tokens_per_forward,
            "position_utilization": self.position_utilization,
        }


@dataclass
class ParallelDecodeAlgorithm:
    """Propose -> verify -> commit driver over one DecodeEngine.

    Subclass protocol:
      parallel_width()        block width for the next step; the default
                              spends the engine's NFP budget (reserving
                              one position for the pending token).
      propose(ctx, pending, n) length-n candidate block (np.int64).
      resolve(pending, drafts) verify + commit; returns (committed
                              tokens — now in the cache after
                              ``pending`` — and the next pending token).
                              Default: one multi-position forward with
                              greedy prefix acceptance, which keeps the
                              output stream identical to AR greedy.
      begin(prompt, pending)  optional hook after target prefill
                              (draft-model setup and the like).
      observe(hidden, k)      optional hook: the verify forward's
                              final-norm hidden states (1, n, d) plus
                              the accepted index k whose logits produced
                              the next pending token (MTP proposes from
                              hidden[0, k]).
    """

    engine: DecodeEngine

    def __post_init__(self):
        self.stats = DecodeStats()

    # ------------------------------------------------------------------
    # protocol (overridable)
    # ------------------------------------------------------------------
    def parallel_width(self) -> int:
        return max(1, self.engine.nfp_budget() - 1)

    def begin(self, prompt: np.ndarray, pending: int) -> None:
        pass

    def observe(self, hidden, k: int) -> None:
        pass

    def propose(self, context: np.ndarray, pending: int,
                n: int) -> np.ndarray:
        raise NotImplementedError

    def resolve(self, pending: int, drafts: np.ndarray
                ) -> Tuple[List[int], int]:
        """Greedy verification: accept the longest draft prefix the
        target model reproduces, plus the model's own next token."""
        block = np.concatenate([[pending], drafts]).astype(np.int64)
        logits, new_cache, hidden = self.forward_block(block)
        # argmax runs jitted on device; only the (n,) i32 winners cross
        # to the host (the accept loop below is inherently host-side)
        preds = np.asarray(greedy_tokens(logits[0]))  # analysis: allow-host-sync
        k = 0
        while k < len(drafts) and preds[k] == drafts[k]:
            k += 1
        self.engine.commit(new_cache, 1 + k)
        self.observe(hidden, k)
        return list(drafts[:k]), int(preds[k])

    # ------------------------------------------------------------------
    # shared machinery
    # ------------------------------------------------------------------
    def forward_block(self, block: np.ndarray):
        """One multi-position decode forward over ``block`` WITHOUT
        committing; tracks forward/position stats.  Returns
        (logits, new_cache, hidden)."""
        eng = self.engine
        toks = jnp.broadcast_to(jnp.asarray(block[None], jnp.int32),
                                (eng.batch, len(block)))
        logits, new_cache, hidden = eng.peek_step(toks)
        self.stats.forwards += 1
        self.stats.positions += len(block)
        return logits, new_cache, hidden

    def generate(self, prompt, max_tokens: int
                 ) -> Tuple[np.ndarray, Dict]:
        """Greedy generation (batch=1 driver).  Returns (tokens, stats)."""
        eng = self.engine
        self.stats = DecodeStats()
        logits = eng.prefill(prompt)
        pending = int(jnp.argmax(logits[0]))
        context = np.asarray(prompt[0]).astype(np.int64)
        generated: List[int] = [pending]
        self.begin(np.asarray(prompt), pending)
        while len(generated) < max_tokens:
            n = min(self.parallel_width(), max_tokens - len(generated))
            drafts = self.propose(context, pending, n)
            committed, next_pending = self.resolve(pending, drafts)
            context = np.concatenate(
                [context, [pending], committed]).astype(np.int64)
            generated.extend(list(committed) + [next_pending])
            pending = next_pending
        self.stats.tokens = len(generated)
        return np.asarray(generated[:max_tokens]), self.stats.as_dict()


class SlotAdapter:
    """Scheduler-side propose → verify → commit adapter.

    ``ServingLoop`` owns admission, slots, telemetry, and retirement;
    the adapter owns what happens INSIDE one scheduler step.  The base
    class is the greedy/speculative shape — every active request's
    pending token (plus optional per-row drafts from ``propose``) rides
    ONE shared multi-position forward, and each row greedily accepts its
    longest reproduced draft prefix, which keeps every stream
    byte-identical to solo greedy decoding.

    Subclass protocol:
      width(n_active, budget)  per-request block width for this step —
                               how the adapter splits the NFP budget
                               across rows (ASPD-style).
      headroom()               cache positions a slot needs beyond
                               prompt + max_tokens (admission check).
      begin(req, hidden)       after the request's slot is prefilled;
                               ``hidden`` is the (d,) final-norm state
                               of its last prompt position.
      propose(req, n)          length-<=n draft block for one row.
      observe(req, k, hidden)  after acceptance: k = accepted index,
                               ``hidden`` the row's (n, d) verify-forward
                               hidden states.
      run_step(slots, width, budget)
                               the whole verify/commit drive; override
                               when verification needs several shared
                               forwards (diffusion refinement).
    """

    mode = "greedy"

    def __init__(self, loop):
        self.loop = loop

    # -- protocol ------------------------------------------------------
    def width(self, n_active: int, budget: int) -> int:
        return 1

    def headroom(self) -> int:
        return 0

    def begin(self, req, hidden) -> None:
        pass

    def propose(self, req, n: int) -> np.ndarray:
        return np.zeros((0,), np.int64)

    def propose_rows(self, want: Dict[int, int]) -> Dict[int, np.ndarray]:
        """Draft blocks for many rows at once: {slot: n} -> {slot:
        drafts}.  Default defers to per-row ``propose``; adapters whose
        proposal is itself a device computation (the MTP head bank)
        override this to run ONE batched dispatch for all rows instead
        of one dispatch + host sync per row per step."""
        return {s: self.propose(self.loop.active[s], n)
                for s, n in want.items()}

    def observe(self, req, k: int, hidden) -> None:
        pass

    # -- default drive: propose / ONE shared forward / greedy accept ---
    def run_step(self, slots: List[int], width: int, budget: int) -> None:
        loop = self.loop
        eng = loop.engine
        tokens = np.zeros((eng.batch, width), np.int64)
        want: Dict[int, int] = {}
        for s in slots:
            req = loop.active[s]
            tokens[s, 0] = req.pending
            # clip each row's drafts to its remaining tokens — budget
            # positions past a request's max_tokens would be discarded
            n_draft = min(width - 1,
                          req.max_tokens - len(req.generated) - 1)
            if n_draft > 0:
                want[s] = n_draft
        drafts: Dict[int, np.ndarray] = {}
        for s, d in (self.propose_rows(want) if want else {}).items():
            d = np.asarray(d, np.int64)[:want[s]]
            if len(d):
                drafts[s] = d
                tokens[s, 1:1 + len(d)] = d
        logits, new_cache, hidden = loop.shared_forward(tokens, budget)
        # greedy winners computed ON DEVICE (jitted); the only per-step
        # device->host transfer is this (batch, width) i32 block — the
        # token stream emission every serving loop fundamentally needs
        preds = np.asarray(greedy_tokens(logits))  # analysis: allow-host-sync
        advances = np.zeros((eng.batch,), np.int32)
        for s in slots:
            req = loop.active[s]
            k = 0
            d = drafts.get(s)
            if d is not None:
                while k < len(d) and preds[s, k] == d[k]:
                    k += 1
                req.generated.extend(int(t) for t in d[:k])
            bonus = int(preds[s, k])
            req.generated.append(bonus)
            advances[s] = 1 + k                  # pending + accepted drafts
            req.pending = bonus
            self.observe(req, k, hidden[s])
        eng.commit_slots(new_cache, advances)
