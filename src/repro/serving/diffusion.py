"""Diffusion-style block decoding (WeDLM-like: causal attention + masked
iterative refinement) — the DLLM side of the paper's validation.

A block of N positions (N = the NFP budget) starts as [MASK] tokens and
is refined over ``refine_steps`` decode forwards; each iteration commits
the most confident still-masked positions.  Every refinement forward is
a multi-position decode forward of exactly N+1 positions, so the block
size is the parallelism knob the NFP budget governs (paper Sec. 6:
"diffusion-style block size").

KV-commit discipline: refinement forwards see MASK tokens at unresolved
positions, so their cache is POISON — a position resolved during (or
after) the final iteration would commit KV computed from a mask-token
input.  Both drivers therefore run one extra forward over the fully
resolved block and commit THAT cache, making the committed KV
byte-identical to prefilling the resolved tokens
(``tests/test_serving_modes.py::test_diffusion_committed_kv_matches_prefill``).

Under the common protocol: ``propose`` emits the mask block and
``resolve`` replaces the single-forward greedy verification with the
iterative refinement loop; ``DiffusionSlotAdapter`` runs the same
refinement over MANY requests at once — each refinement iteration is
ONE shared multi-position forward whose width still fits the NFP budget
split across the active rows.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.algorithm import ParallelDecodeAlgorithm, SlotAdapter
from repro.serving.engine import DecodeEngine

Array = jax.Array


def refine_block(block: np.ndarray, resolved: np.ndarray, lg: np.ndarray,
                 per_iter: int) -> None:
    """One refinement update in place: freeze the ``per_iter`` most
    confident still-masked positions of ``block`` given the float32
    logits ``lg`` ((>=n+1, vocab); row i predicts block position i)."""
    n = len(block)
    probs = np.exp(lg - lg.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    conf = probs.max(-1)[:n]
    preds = probs.argmax(-1)[:n]
    cand = np.where(~resolved)[0]
    order = cand[np.argsort(-conf[cand])]
    pick = order[:per_iter]
    block[pick] = preds[pick]
    resolved[pick] = True


@dataclass
class DiffusionBlockDecoder(ParallelDecodeAlgorithm):
    engine: DecodeEngine
    block_size: Optional[int] = None     # None -> NFP budget
    refine_steps: int = 4
    mask_id: Optional[int] = None        # None -> vocab_size - 1

    def __post_init__(self):
        super().__post_init__()
        if self.refine_steps < 1:
            raise ValueError(f"refine_steps must be >= 1, "
                             f"got {self.refine_steps}")

    def _block(self) -> int:
        if self.block_size is not None:
            return self.block_size
        return max(1, self.engine.nfp_budget() - 1)

    parallel_width = _block

    def _mask_id(self) -> int:
        if self.mask_id is not None:
            return self.mask_id
        return self.engine.cfg.vocab_size - 1

    def propose(self, context: np.ndarray, pending: int,
                n: int) -> np.ndarray:
        return np.full((n,), self._mask_id(), np.int64)

    def resolve(self, pending: int, drafts: np.ndarray
                ) -> Tuple[List[int], int]:
        """Iterative refinement: each forward re-predicts the block and
        the most confident still-masked positions freeze.  A FINAL
        forward over the fully-resolved block produces the cache that
        commits — the refinement forwards' caches hold KV computed from
        mask-token inputs and must never reach the engine."""
        n = len(drafts)
        block = np.asarray(drafts, np.int64).copy()
        resolved = np.zeros((n,), bool)
        per_iter = max(1, int(np.ceil(n / self.refine_steps)))
        step_logits = None
        for _ in range(self.refine_steps):
            if resolved.all():
                break
            step_logits, _, _ = self.forward_block(
                np.concatenate([[pending], block]))
            refine_block(block, resolved,
                         np.asarray(step_logits[0].astype(jnp.float32)),
                         per_iter)
        if not resolved.all():
            block[~resolved] = np.asarray(
                jnp.argmax(step_logits[0], axis=-1))[:n][~resolved]
        # commit forward: KV for [pending] + block[:-1] computed from the
        # RESOLVED tokens (byte-identical to prefilling them)
        _, new_cache, _ = self.forward_block(
            np.concatenate([[pending], block]))
        self.engine.commit(new_cache, n)
        return list(block[:-1]), int(block[-1])


class DiffusionSlotAdapter(SlotAdapter):
    """Scheduler-side diffusion block refinement: every active request
    refines its own block, but each refinement iteration is ONE shared
    multi-position forward over all rows — the scheduler's NFP budget
    split covers ``n_active * (block + 1)`` positions per forward, so
    the block size shrinks as concurrency grows (the DLLM counterpart of
    the speculative width split).  Rows that resolve early simply ride
    along untouched until the slowest row finishes, and the final commit
    forward (fully-resolved blocks, see module docstring) is shared too.
    """

    mode = "diffusion"

    def __init__(self, loop, block_size: Optional[int] = None,
                 refine_steps: int = 4, mask_id: Optional[int] = None):
        super().__init__(loop)
        if refine_steps < 1:
            raise ValueError(f"refine_steps must be >= 1, "
                             f"got {refine_steps}")
        self.block_size = block_size
        self.refine_steps = refine_steps
        self.mask_id = mask_id

    def _mask_id(self) -> int:
        if self.mask_id is not None:
            return self.mask_id
        return self.loop.engine.cfg.vocab_size - 1

    def width(self, n_active: int, budget: int) -> int:
        if self.block_size is not None:
            n = self.block_size
        else:
            # each refinement forward carries (block + 1) positions/row
            n = max(1, budget // max(n_active, 1) - 1)
        return min(n, self.loop.max_width)

    def headroom(self) -> int:
        return self.loop.max_width

    def run_step(self, slots: List[int], width: int, budget: int) -> None:
        loop = self.loop
        eng = loop.engine
        mask_id = self._mask_id()
        # per-row block sizes, clipped to each request's remaining tokens
        n: Dict[int, int] = {}
        blocks: Dict[int, np.ndarray] = {}
        resolved: Dict[int, np.ndarray] = {}
        for s in slots:
            req = loop.active[s]
            n[s] = max(1, min(width, req.max_tokens - len(req.generated)))
            blocks[s] = np.full((n[s],), mask_id, np.int64)
            resolved[s] = np.zeros((n[s],), bool)
        w = max(n.values())

        def block_tokens() -> np.ndarray:
            tokens = np.zeros((eng.batch, w + 1), np.int64)
            for s in slots:
                tokens[s, 0] = loop.active[s].pending
                tokens[s, 1:1 + n[s]] = blocks[s]
            return tokens

        last_lg: Dict[int, np.ndarray] = {}
        for _ in range(self.refine_steps):
            if all(resolved[s].all() for s in slots):
                break
            logits, _, _ = loop.shared_forward(block_tokens(), budget)
            for s in slots:
                if resolved[s].all():
                    continue
                lg = np.asarray(logits[s].astype(jnp.float32))
                last_lg[s] = lg
                refine_block(blocks[s], resolved[s], lg,
                             max(1, int(np.ceil(n[s] / self.refine_steps))))
        for s in slots:
            if not resolved[s].all():
                blocks[s][~resolved[s]] = (
                    last_lg[s].argmax(-1)[:n[s]][~resolved[s]])
        # shared commit forward over the fully-resolved blocks — the only
        # cache that reaches the engine
        _, new_cache, _ = loop.shared_forward(block_tokens(), budget)
        advances = np.zeros((eng.batch,), np.int32)
        for s in slots:
            req = loop.active[s]
            req.generated.extend(int(t) for t in blocks[s])
            advances[s] = n[s]                   # pending + block[:-1]
            req.pending = int(blocks[s][-1])
        eng.commit_slots(new_cache, advances)
