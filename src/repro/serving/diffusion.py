"""Diffusion-style block decoding (WeDLM-like: causal attention + masked
iterative refinement) — the DLLM side of the paper's validation.

A block of N positions (N = the NFP budget) starts as [MASK] tokens and
is refined over ``refine_steps`` decode forwards; each iteration commits
the most confident still-masked positions.  Every refinement forward is
a multi-position decode forward of exactly N+1 positions, so the block
size is the parallelism knob the NFP budget governs (paper Sec. 6:
"diffusion-style block size").

Under the common protocol: ``propose`` emits the mask block and
``resolve`` replaces the single-forward greedy verification with the
iterative refinement loop — commit arithmetic and stats stay inherited.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.algorithm import ParallelDecodeAlgorithm
from repro.serving.engine import DecodeEngine

Array = jax.Array


@dataclass
class DiffusionBlockDecoder(ParallelDecodeAlgorithm):
    engine: DecodeEngine
    block_size: Optional[int] = None     # None -> NFP budget
    refine_steps: int = 4
    mask_id: Optional[int] = None        # None -> vocab_size - 1

    def _block(self) -> int:
        if self.block_size is not None:
            return self.block_size
        return max(1, self.engine.nfp_budget() - 1)

    parallel_width = _block

    def _mask_id(self) -> int:
        if self.mask_id is not None:
            return self.mask_id
        return self.engine.cfg.vocab_size - 1

    def propose(self, context: np.ndarray, pending: int,
                n: int) -> np.ndarray:
        return np.full((n,), self._mask_id(), np.int64)

    def resolve(self, pending: int, drafts: np.ndarray
                ) -> Tuple[List[int], int]:
        """Iterative refinement: each forward re-predicts the block, the
        most confident still-masked positions freeze, and the final
        forward's cache (which saw the fully-resolved block) commits."""
        n = len(drafts)
        block = np.asarray(drafts, np.int64).copy()
        resolved = np.zeros((n,), bool)
        per_iter = max(1, int(np.ceil(n / self.refine_steps)))
        step_logits, new_cache = None, None
        for _ in range(self.refine_steps):
            if resolved.all():
                break
            step_logits, new_cache = self.forward_block(
                np.concatenate([[pending], block]))
            lg = np.asarray(step_logits[0].astype(jnp.float32))
            # position i of the block is predicted by logits row i
            probs = np.exp(lg - lg.max(-1, keepdims=True))
            probs /= probs.sum(-1, keepdims=True)
            conf = probs.max(-1)[:n]
            preds = probs.argmax(-1)[:n]
            cand = np.where(~resolved)[0]
            order = cand[np.argsort(-conf[cand])]
            pick = order[:per_iter]
            block[pick] = preds[pick]
            resolved[pick] = True
        block[~resolved] = np.asarray(
            jnp.argmax(step_logits[0], axis=-1))[:n][~resolved]
        # commit: final forward wrote KV for [pending] + block[:-1]
        self.engine.commit(new_cache, n)
        return list(block[:-1]), int(block[-1])
