"""Diffusion-style block decoding (WeDLM-like: causal attention + masked
iterative refinement) — the DLLM side of the paper's validation.

A block of N positions (N = the NFP budget) starts as [MASK] tokens and
is refined over ``refine_steps`` decode forwards; each iteration commits
the most confident still-masked positions.  Every refinement forward is a
multi-position decode forward of exactly N+1 positions, so the block size
is the parallelism knob the NFP budget governs (paper Sec. 6:
"diffusion-style block size").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import DecodeEngine

Array = jax.Array


@dataclass
class DiffusionBlockDecoder:
    engine: DecodeEngine
    block_size: Optional[int] = None     # None -> NFP budget
    refine_steps: int = 4
    mask_id: Optional[int] = None        # None -> vocab_size - 1

    def _block(self) -> int:
        if self.block_size is not None:
            return self.block_size
        return max(1, self.engine.nfp_budget() - 1)

    def generate(self, prompt: Array, max_tokens: int
                 ) -> Tuple[np.ndarray, dict]:
        eng = self.engine
        mask_id = (self.mask_id if self.mask_id is not None
                   else eng.cfg.vocab_size - 1)
        logits = eng.prefill(prompt)
        pending = int(jnp.argmax(logits[0]))
        generated = [pending]
        n_forwards, n_positions = 0, 0
        while len(generated) < max_tokens:
            n = min(self._block(), max_tokens - len(generated))
            block = np.full((n,), mask_id, np.int64)
            resolved = np.zeros((n,), bool)
            per_iter = max(1, int(np.ceil(n / self.refine_steps)))
            new_cache = None
            for _ in range(self.refine_steps):
                if resolved.all():
                    break
                toks = np.concatenate([[pending], block])
                tj = jnp.broadcast_to(jnp.asarray(toks[None], jnp.int32),
                                      (eng.batch, n + 1))
                step_logits, new_cache = eng.peek_step(tj)
                n_forwards += 1
                n_positions += n + 1
                lg = np.asarray(step_logits[0].astype(jnp.float32))
                # position i of the block is predicted by logits row i
                probs = np.exp(lg - lg.max(-1, keepdims=True))
                probs /= probs.sum(-1, keepdims=True)
                conf = probs.max(-1)[:n]
                preds = probs.argmax(-1)[:n]
                cand = np.where(~resolved)[0]
                order = cand[np.argsort(-conf[cand])]
                pick = order[:per_iter]
                block[pick] = preds[pick]
                resolved[pick] = True
            block[~resolved] = np.asarray(
                jnp.argmax(step_logits[0], axis=-1))[:n][~resolved]
            # commit: final forward wrote KV for [pending] + block
            eng.commit(new_cache, 1 + (n - 1))
            generated.extend(block.tolist())
            pending = int(block[-1])
        stats = {
            "tokens": len(generated),
            "forwards": n_forwards,
            "positions": n_positions,
            "tokens_per_forward": len(generated) / max(n_forwards, 1),
            "position_utilization": len(generated) / max(n_positions, 1),
        }
        return np.asarray(generated[:max_tokens]), stats
