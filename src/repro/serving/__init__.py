"""repro.serving — multi-position decode engine + parallel-decoding drivers."""
from repro.serving.diffusion import DiffusionBlockDecoder
from repro.serving.engine import DecodeEngine
from repro.serving.mtp import MTPDecoder, init_mtp_heads, mtp_loss
from repro.serving.speculative import SpeculativeDecoder, ngram_draft

__all__ = ["DecodeEngine", "SpeculativeDecoder", "DiffusionBlockDecoder",
           "MTPDecoder", "init_mtp_heads", "mtp_loss", "ngram_draft"]
