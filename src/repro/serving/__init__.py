"""repro.serving — multi-position decode engine, the common parallel-
decoding protocol, algorithm drivers, and the multi-request scheduler."""
from repro.serving.algorithm import DecodeStats, ParallelDecodeAlgorithm
from repro.serving.diffusion import DiffusionBlockDecoder
from repro.serving.engine import DecodeEngine
from repro.serving.mtp import MTPDecoder, init_mtp_heads, mtp_loss
from repro.serving.scheduler import Request, ServingLoop
from repro.serving.speculative import SpeculativeDecoder, ngram_draft

__all__ = ["DecodeEngine", "DecodeStats", "ParallelDecodeAlgorithm",
           "SpeculativeDecoder", "DiffusionBlockDecoder", "MTPDecoder",
           "Request", "ServingLoop", "init_mtp_heads", "mtp_loss",
           "ngram_draft"]
