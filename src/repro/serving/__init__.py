"""repro.serving — multi-position decode engine, the common parallel-
decoding protocol (solo drivers + scheduler-side slot adapters), and the
multi-request scheduler."""
from repro.serving.algorithm import (DecodeStats, ParallelDecodeAlgorithm,
                                     SlotAdapter)
from repro.serving.diffusion import DiffusionBlockDecoder, DiffusionSlotAdapter
from repro.serving.engine import DecodeEngine
from repro.serving.mtp import (MTPDecoder, MTPSlotAdapter, init_mtp_heads,
                               mtp_loss)
from repro.serving.paged import (BlockAllocator, BlockManager, PagedKVConfig,
                                 PrefixCache)
from repro.serving.scheduler import (DEFAULT_SLO_CLASSES, AdmissionConfig,
                                     AdmissionRejected, Request, SLOClass,
                                     ServingLoop)
from repro.serving.speculative import (SpeculativeDecoder,
                                       SpeculativeSlotAdapter, ngram_draft)

__all__ = ["AdmissionConfig", "AdmissionRejected", "BlockAllocator",
           "BlockManager", "DecodeEngine", "DecodeStats",
           "DEFAULT_SLO_CLASSES", "ParallelDecodeAlgorithm", "PagedKVConfig",
           "PrefixCache", "SLOClass", "SlotAdapter", "SpeculativeDecoder",
           "SpeculativeSlotAdapter", "DiffusionBlockDecoder",
           "DiffusionSlotAdapter", "MTPDecoder", "MTPSlotAdapter", "Request",
           "ServingLoop", "init_mtp_heads", "mtp_loss", "ngram_draft"]
