"""Paged KV cache bookkeeping: block pool, prefix cache, block tables.

The dense per-slot cache sizes KV memory as ``slots x max_len`` whether
or not the slots are full.  Paging replaces it with a global pool of
fixed-size blocks (vLLM-style): each slot owns a *block table* mapping
logical block index -> physical pool block, blocks are refcounted, and
identical prompt prefixes resolve to the SAME physical blocks through a
hash-of-prefix cache — admission then skips prefill for the shared
portion and only computes the divergent suffix.

This module is pure host-side bookkeeping (numpy + python): it decides
WHICH physical block every position lives in; the device-side pool
arrays live in the engine's cache pytree and are indexed by the block
tables this module maintains (``models.attention`` scatter/gather and
the block-table-indexed Pallas kernel in ``kernels.decode_attention``).

Block lifecycle / refcount semantics:
  - ``alloc()`` hands a free block to one slot (refcount 1).
  - attaching a cached block to another slot increfs it.
  - registering a full prompt block in the prefix cache increfs it once
    (the cache's own hold), so the block outlives its slot.
  - ``release(slot)`` decrefs every block the slot holds; blocks whose
    only remaining hold is the prefix cache stay resident (hit-able)
    until LRU eviction recycles them under allocation pressure.

Copy-on-write: writes may only touch blocks with refcount 1.  When the
divergence point of a prefix hit falls INSIDE a shared block (a fully
cached prompt re-computes its last position), the shared block is copied
into a fresh one at admission and the slot's table is repointed — the
classic COW-at-the-divergence-block move, surfaced to the engine as a
(src, dst) device-copy list.

The last physical block of the pool is a write dump ("trash" block):
unattached block-table entries point at it, so batched forwards that
write junk rows (inactive slots, bucket padding) land somewhere harmless
instead of corrupting live blocks.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.granularity import cdiv

__all__ = ["PagedKVConfig", "BlockAllocator", "PrefixCache", "BlockManager",
           "AdmitResult"]


@dataclass(frozen=True)
class PagedKVConfig:
    """Paged-cache knobs (``launch.serve --kv-block-size / --kv-blocks``).

    ``block_size`` is the paging granularity in positions — with the
    Pallas path it is also the kernel's kv tile (the k_block), which is
    how paging enters the NFP granularity accounting.  ``n_blocks`` is
    the pool size in blocks (default: enough for ``batch`` dense slots,
    i.e. memory parity with the dense cache; smaller pools trade
    capacity for admission backpressure).  ``prefix_cache`` toggles
    hash-of-prefix block reuse.
    """

    block_size: int = 128
    n_blocks: Optional[int] = None
    prefix_cache: bool = True


@dataclass
class AdmitResult:
    """What admission decided for one slot."""

    cached_len: int                  # prompt positions served from cache
    cow_copies: List[Tuple[int, int]] = field(default_factory=list)
    new_blocks: int = 0              # freshly allocated (incl. COW copies)


class BlockAllocator:
    """Refcounted free-list allocator over ``n_blocks`` physical blocks."""

    def __init__(self, n_blocks: int):
        if n_blocks < 1:
            raise ValueError(f"need at least one block, got {n_blocks}")
        self.n_blocks = n_blocks
        self.refcount = np.zeros((n_blocks,), np.int32)
        self._free: Deque[int] = deque(range(n_blocks))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_blocks - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("KV block pool exhausted")
        b = self._free.popleft()
        if self.refcount[b] != 0:
            raise RuntimeError(
                f"block {b} was on the free list with refcount "
                f"{self.refcount[b]}")
        self.refcount[b] = 1
        return b

    def incref(self, b: int) -> None:
        if self.refcount[b] <= 0:
            raise RuntimeError(f"incref on free block {b}")
        self.refcount[b] += 1

    def decref(self, b: int) -> bool:
        """Drop one hold; returns True when the block became free."""
        if self.refcount[b] <= 0:
            raise RuntimeError(f"decref on free block {b}")
        self.refcount[b] -= 1
        if self.refcount[b] == 0:
            self._free.append(b)
            return True
        return False


class PrefixCache:
    """hash-of-prefix -> physical block, LRU-ordered (front = coldest).

    Keys are exact chained prefixes (nested tuples), so a hit guarantees
    token-identical content — the repro trades the constant-size hashing
    of production stacks for collision-free bookkeeping.
    """

    def __init__(self):
        self._table: "OrderedDict[tuple, int]" = OrderedDict()
        self._key_of: Dict[int, tuple] = {}

    def __len__(self) -> int:
        return len(self._table)

    @staticmethod
    def chain_keys(tokens: Sequence[int], block_size: int) -> List[tuple]:
        """One key per FULL block of ``tokens``; key i commits to the
        entire prefix through block i (chained), not just block i."""
        keys, prev = [], None
        for i in range(len(tokens) // block_size):
            blk = tuple(int(t) for t in
                        tokens[i * block_size:(i + 1) * block_size])
            prev = (prev, blk)
            keys.append(prev)
        return keys

    def get(self, key: tuple) -> Optional[int]:
        b = self._table.get(key)
        if b is not None:
            self._table.move_to_end(key)
        return b

    def peek(self, key: tuple) -> Optional[int]:
        """Lookup WITHOUT touching LRU order — for feasibility queries
        (can_admit runs every scheduler step for the queue head; letting
        it refresh recency would let a never-admitted request pin its
        prefix at the MRU end and distort eviction)."""
        return self._table.get(key)

    def put(self, key: tuple, block: int) -> bool:
        """Register ``block`` under ``key``; keeps an earlier entry
        (first writer wins) and reports whether the put took."""
        if key in self._table:
            return False
        self._table[key] = block
        self._key_of[block] = key
        return True

    def holds(self, block: int) -> bool:
        return block in self._key_of

    def evict_lru(self, evictable) -> Optional[int]:
        """Drop the least-recently-used entry whose block ``evictable``
        approves (refcount == 1: the cache is the sole holder)."""
        for key, block in self._table.items():
            if evictable(block):
                del self._table[key]
                del self._key_of[block]
                return block
        return None


class BlockManager:
    """Per-slot block tables over one allocator + prefix cache.

    Admission is EAGER: ``admit`` attaches cached prefix blocks, performs
    any divergence-block COW, and allocates every block the request can
    touch over its lifetime (``reserve_len`` positions: prompt +
    max_tokens + adapter headroom) — so decode-time writes never allocate
    and can never fail mid-flight.  The scheduler gates admission on
    ``can_admit`` (free + evictable blocks), the paged analogue of
    "is a slot free".
    """

    def __init__(self, batch: int, max_len: int, block_size: int,
                 n_blocks: int, prefix_cache: bool = True):
        if max_len % block_size != 0:
            raise ValueError(f"max_len={max_len} must be a multiple of "
                             f"kv block_size={block_size}")
        self.batch = batch
        self.max_len = max_len
        self.block_size = block_size
        self.max_blocks = max_len // block_size
        self.allocator = BlockAllocator(n_blocks)
        self.prefix = PrefixCache() if prefix_cache else None
        self.trash = n_blocks               # the extra write-dump block
        self.tables = np.full((batch, self.max_blocks), self.trash, np.int32)
        self._held: List[List[int]] = [[] for _ in range(batch)]
        # telemetry the scheduler surfaces in stats()
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.cow_copies = 0
        self.evictions = 0
        self.peak_blocks_used = 0
        self.preemptions = 0
        self.preempt_blocks_freed = 0

    # ------------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return self.allocator.n_blocks

    @property
    def n_phys(self) -> int:
        """Physical pool blocks including the trailing trash block."""
        return self.allocator.n_blocks + 1

    def blocks_used(self) -> int:
        return self.allocator.n_used

    def n_evictable(self) -> int:
        if self.prefix is None:
            return 0
        return sum(1 for b in self.prefix._key_of
                   if self.allocator.refcount[b] == 1)

    def available_blocks(self) -> int:
        return self.allocator.n_free + self.n_evictable()

    # ------------------------------------------------------------------
    def _match(self, tokens: Sequence[int]) -> Tuple[int, List[tuple]]:
        """Longest chain of cached full blocks matching ``tokens``.
        Read-only (no LRU touch) — ``admit``'s attach loop refreshes
        recency for the blocks it actually takes."""
        keys = (PrefixCache.chain_keys(tokens, self.block_size)
                if self.prefix is not None else [])
        matched = 0
        for key in keys:
            if self.prefix.peek(key) is None:
                break
            matched += 1
        return matched, keys

    def admission_cost(self, tokens: Sequence[int],
                       reserve_len: int) -> Tuple[int, int]:
        """(fresh blocks ``admit`` would allocate, currently-evictable
        cached blocks the admission would PIN by attaching).  Pinned
        blocks don't consume pool space but do shrink the evictable
        supply, so admission gating must budget ``needed + pinned``."""
        p = len(tokens)
        if reserve_len < p:
            raise ValueError("reserve_len must cover the prompt")
        matched, keys = self._match(tokens)
        cached_len = min(matched * self.block_size, p - 1)
        needed = (cdiv(reserve_len, self.block_size)
                  - cached_len // self.block_size)
        cow = cached_len < matched * self.block_size
        pinned = 0
        for i, key in enumerate(keys[:matched]):
            if cow and i == matched - 1:
                # the COW source is not pinned: admit drops its hold on
                # it before allocating the copy (the copy itself is
                # already in ``needed``), so it stays evictable —
                # counting it too would gate a feasible admission out
                # forever on a tight pool
                continue
            b = self.prefix.peek(key)
            if b is not None and self.allocator.refcount[b] == 1:
                pinned += 1
        return needed, pinned

    def can_admit(self, tokens: Sequence[int], reserve_len: int) -> bool:
        needed, pinned = self.admission_cost(tokens, reserve_len)
        return needed + pinned <= self.available_blocks()

    # ------------------------------------------------------------------
    def _alloc_or_evict(self) -> int:
        if self.allocator.n_free == 0 and self.prefix is not None:
            victim = self.prefix.evict_lru(
                lambda b: self.allocator.refcount[b] == 1)
            if victim is not None:
                self.allocator.decref(victim)      # the cache's hold
                self.evictions += 1
        b = self.allocator.alloc()
        self.peak_blocks_used = max(self.peak_blocks_used,
                                    self.allocator.n_used)
        return b

    def admit(self, slot: int, tokens: Sequence[int],
              reserve_len: int) -> AdmitResult:
        """Build slot ``slot``'s block table for a request of
        ``len(tokens)`` prompt positions and ``reserve_len`` total
        positions.  Returns the cached prefix length and any COW
        device copies the engine must apply BEFORE writing.

        At least one prompt position is always recomputed (the last-
        position logits seed generation), so a fully cached prompt caps
        ``cached_len`` at ``p - 1`` — the divergence then falls inside
        the final shared block and triggers the COW copy.
        """
        p = len(tokens)
        if p < 1:
            raise ValueError("empty prompt")
        if reserve_len < p or reserve_len > self.max_len:
            raise ValueError(f"reserve_len={reserve_len} outside "
                             f"[prompt={p}, max_len={self.max_len}]")
        if self._held[slot]:
            raise RuntimeError(f"slot {slot} already admitted")
        bs = self.block_size
        matched, keys = self._match(tokens)
        cached_len = min(matched * bs, p - 1)

        held: List[int] = []
        result = AdmitResult(cached_len=cached_len)
        snapshot = (self.cow_copies,)
        try:
            # attach the matched read-only prefix blocks
            for i in range(matched):
                b = self.prefix.get(keys[i])
                self.allocator.incref(b)
                self.tables[slot, i] = b
                held.append(b)
            # divergence inside the last shared block -> copy-on-write.
            # Drop our hold on the source BEFORE allocating the copy:
            # the source stays resident under the cache's hold, remains
            # evictable, and may even legitimately be the block LRU
            # eviction hands back as the copy target (an identity copy)
            # — this keeps admission_cost's supply math exact.
            if cached_len < matched * bs:
                src = int(self.tables[slot, matched - 1])
                held[matched - 1] = None
                self.allocator.decref(src)
                dst = self._alloc_or_evict()
                result.cow_copies.append((src, dst))
                result.new_blocks += 1
                self.cow_copies += 1
                self.tables[slot, matched - 1] = dst
                held[matched - 1] = dst
            # fresh blocks for suffix + generation + headroom reservation
            for i in range(matched, cdiv(reserve_len, bs)):
                b = self._alloc_or_evict()
                result.new_blocks += 1
                self.tables[slot, i] = b
                held.append(b)
        except RuntimeError:
            # atomic admission: a mid-flight pool exhaustion rolls every
            # hold back so refcount invariants survive the failure
            # (evictions already performed are real and stay; a None
            # placeholder marks the COW source whose hold was already
            # dropped)
            for b in held:
                if b is not None:
                    self.allocator.decref(b)
            self.tables[slot, :] = self.trash
            (self.cow_copies,) = snapshot
            raise
        self._held[slot] = held
        self.lookups += 1
        if cached_len > 0:
            self.hits += 1
            self.hit_tokens += cached_len
        return result

    def register_prompt(self, slot: int, tokens: Sequence[int]) -> int:
        """Register the slot's full prompt blocks in the prefix cache
        (call AFTER prefill has filled them).  First writer wins: a key
        already cached keeps its existing block.  Returns the number of
        newly registered blocks (each takes one cache hold)."""
        if self.prefix is None:
            return 0
        new = 0
        for i, key in enumerate(PrefixCache.chain_keys(tokens,
                                                       self.block_size)):
            b = int(self.tables[slot, i])
            if self.prefix.put(key, b):
                self.allocator.incref(b)
                new += 1
        return new

    def release(self, slot: int) -> int:
        """Drop the slot's holds; prefix-cached blocks stay resident
        under the cache's own hold until eviction recycles them.
        Returns how many blocks became free."""
        freed = 0
        for b in self._held[slot]:
            if self.allocator.decref(b):
                freed += 1
        self._held[slot] = []
        self.tables[slot, :] = self.trash
        return freed

    def preempt(self, slot: int) -> int:
        """Eviction-by-preemption: same hold-dropping as ``release`` but
        counted separately — the scheduler evicts a LIVE request whose
        KV will be recomputed at resume, so these frees measure wasted
        (to-be-recomputed) work, not retirement.  Blocks the prefix
        cache also holds survive; a resume whose context still matches
        them skips that recompute."""
        freed = self.release(slot)
        self.preemptions += 1
        self.preempt_blocks_freed += freed
        return freed

    # ------------------------------------------------------------------
    def device_tables(self) -> np.ndarray:
        """(batch, max_blocks) int32 snapshot for the decode forward."""
        return self.tables.copy()

    def check_invariants(self) -> None:
        """Refcount of every block == holds by slots + the prefix cache
        hold; free blocks appear in no table row and no cache entry."""
        holds = np.zeros((self.n_blocks,), np.int64)
        for held in self._held:
            for b in held:
                holds[b] += 1
        if self.prefix is not None:
            for b in self.prefix._key_of:
                holds[b] += 1
        if not np.array_equal(holds, self.allocator.refcount.astype(np.int64)):
            bad = np.nonzero(holds !=
                             self.allocator.refcount.astype(np.int64))[0]
            raise AssertionError(f"refcount drift on blocks {bad.tolist()}")
        free = set(self.allocator._free)
        for b in free:
            if self.allocator.refcount[b] != 0:
                raise AssertionError(f"free block {b} has refcount")
        used_in_tables = set(int(b) for row in self._held for b in row)
        if used_in_tables & free:
            raise AssertionError("held block on the free list")

    def stats(self) -> Dict[str, int]:
        return {
            "kv_blocks": self.n_blocks,
            "kv_block_size": self.block_size,
            "kv_blocks_used": self.blocks_used(),
            "kv_blocks_peak": self.peak_blocks_used,
            "prefix_lookups": self.lookups,
            "prefix_hits": self.hits,
            "prefix_hit_tokens": self.hit_tokens,
            "cow_copies": self.cow_copies,
            "prefix_evictions": self.evictions,
            "kv_preemptions": self.preemptions,
            "kv_preempt_blocks_freed": self.preempt_blocks_freed,
        }
