"""Multi-position decode engine.

The engine executes the paper's abstraction directly: a decode forward
that processes N positions (Eq. 2) over a pre-allocated cache.  One
compiled executable serves every step at a given N (cache_len is a traced
scalar), matching the bucketed-compile discipline of TPU serving stacks.

The NFP budget (core.parallelism_budget) tells algorithm drivers
(speculative verification, diffusion block decode) how many positions are
near-free for the current arch x hardware x batch x context.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arch import LAYER_ATTN, ArchConfig
from repro.core.granularity import GranularitySpec
from repro.core.hardware import TPU_V5E, HardwareSpec
from repro.core.nfp import parallelism_budget
from repro.models.transformer import (forward, init_cache, init_paged_cache,
                                      make_segments)
from repro.serving.paged import BlockManager, PagedKVConfig

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def _prefill_fn(params, cfg: ArchConfig, tokens, cache, use_kernel=False):
    logits, cache, _, hidden = forward(params, cfg, {"tokens": tokens},
                                       mode="prefill", cache=cache,
                                       cache_len=0, use_kernel=use_kernel)
    return logits, cache, hidden


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def _decode_fn(params, cfg: ArchConfig, tokens, cache, cache_len,
               use_kernel=False):
    logits, cache, _, hidden = forward(params, cfg, {"tokens": tokens},
                                       mode="decode", cache=cache,
                                       cache_len=cache_len,
                                       use_kernel=use_kernel)
    return logits, cache, hidden


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def _decode_paged_fn(params, cfg: ArchConfig, tokens, cache, slot_lens,
                     block_tables, use_kernel=False):
    logits, cache, _, hidden = forward(params, cfg, {"tokens": tokens},
                                       mode="decode", cache=cache,
                                       cache_len=slot_lens,
                                       use_kernel=use_kernel,
                                       block_tables=block_tables)
    return logits, cache, hidden


@jax.jit
def greedy_tokens(logits):
    """Greedy token selection ON DEVICE.  Verify loops call this and
    transfer only the small (b, n) int32 result to the host — pulling
    the raw (b, n, vocab) logits across per step is the kind of
    hot-path transfer ``repro.analysis`` exists to flag."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


@jax.jit
def _copy_pool_blocks(cache, src, dst):
    """Copy pool blocks src -> dst across every layer (the COW device
    op).  Pool leaves are (layers, n_phys, block, ...): index axis 1."""
    return jax.tree.map(lambda pool: pool.at[:, dst].set(pool[:, src]), cache)


@jax.jit
def _scatter_prefill(cache, scratch, flat_idx, rows, cols):
    """Move freshly prefilled KV from the dense scratch cache into pool
    pages: scratch[(row, col)] -> pool_flat[flat_idx], per layer.
    Padding entries target the trash page (duplicate-index writes there
    are harmless)."""
    def one(pool, scr):
        n_phys, bs = pool.shape[1], pool.shape[2]
        flat = pool.reshape((pool.shape[0], n_phys * bs) + pool.shape[3:])
        flat = flat.at[:, flat_idx].set(scr[:, rows, cols])
        return flat.reshape(pool.shape)
    return jax.tree.map(one, cache, scratch)


@dataclass
class DecodeEngine:
    """``paged=PagedKVConfig(...)`` switches the slotted serving mode
    onto the paged KV cache: ``cache`` becomes a global refcounted block
    pool (``init_paged_cache``) shared by all slots through the
    ``BlockManager``'s per-slot block tables, and admissions whose
    prompt prefix is already resident skip prefill for the shared
    blocks.  Paged mode serves attention-only archs via the slotted API
    (``prefill_slots``/``decode_slots``/``commit_slots``); the
    single-request scalar-``cache_len`` drivers stay dense."""

    cfg: ArchConfig
    params: Dict
    batch: int
    max_len: int
    hardware: HardwareSpec = TPU_V5E
    use_kernel: bool = False
    cache: Optional[Dict] = None
    # committed positions of the single-request drivers.  A HOST int on
    # purpose: every step's budget/width decision reads it, and a device
    # scalar here cost one blocking device->host sync per decode step
    # (it is re-uploaded as a traced scalar by the jitted forwards, which
    # is cheap and non-blocking in the other direction).
    cache_len: int = 0
    paged: Optional[PagedKVConfig] = None

    def __post_init__(self):
        self.manager: Optional[BlockManager] = None
        if self.paged is not None:
            if self.cfg.encoder is not None or any(
                    kind != LAYER_ATTN for kind, _ in make_segments(self.cfg)):
                raise ValueError(
                    "paged KV cache requires an attention-only decoder "
                    f"(no SSM/hybrid segments, no encoder); got {self.cfg.name}")
            bs = self.paged.block_size
            n_blocks = (self.paged.n_blocks if self.paged.n_blocks
                        else self.batch * (self.max_len // max(bs, 1)))
            self.manager = BlockManager(self.batch, self.max_len, bs,
                                        n_blocks, self.paged.prefix_cache)
            if self.cache is None:
                self.cache = init_paged_cache(self.cfg, self.manager.n_phys,
                                              bs)
        elif self.cache is None:
            self.cache = init_cache(self.cfg, self.batch, self.max_len)
        self.gran = GranularitySpec.for_backend(
            self.cfg.ffn.n_experts,
            head_dim=(self.cfg.attention.head_dim if self.cfg.attention
                      else 128),
            kv_page=(self.paged.block_size if self.paged else 0))
        # per-slot cache lengths for the scheduler's slotted mode; the
        # single-request drivers keep using the scalar ``cache_len``.
        # ``slot_lens`` rides the jitted decode forwards (per-row ragged
        # lengths), ``slot_lens_host`` is its host-side mirror: every
        # update comes from host values (prompt lengths, accepted
        # counts), so the scheduler's budget/admission math never has to
        # block on a device read mid-decode.
        self.slot_lens = jnp.zeros((self.batch,), jnp.int32)
        self.slot_lens_host = np.zeros((self.batch,), np.int64)
        self._bt_device: Optional[Array] = None
        # (b, d) final-norm hidden of the last prefilled position (MTP
        # proposals read it); one entry per bucketed prefill forward
        self.last_hidden: Optional[Array] = None
        self.prefill_log: List[Dict] = []
        self.preempted_slots = 0               # preempt_slot() evictions

    def _require_dense(self, what: str) -> None:
        if self.manager is not None:
            raise RuntimeError(
                f"{what} drives the aligned dense cache; a paged engine "
                "serves through prefill_slots/decode_slots/commit_slots")

    def _device_tables(self) -> Array:
        """Device copy of the block tables, cached between admissions —
        tables only change at admit/COW/release, so re-uploading every
        decode step would be pure repeated host->device traffic."""
        if self._bt_device is None:
            self._bt_device = jnp.asarray(self.manager.device_tables())
        return self._bt_device

    # ------------------------------------------------------------------
    def nfp_budget(self, eps: float = 0.2, routing: str = "balanced",
                   ell: Optional[int] = None) -> int:
        """Near-free position budget for the CURRENT state (Sec. 6).

        Pure host math: ``cache_len`` is the host-side committed length,
        so a per-step budget query costs no device synchronization."""
        if ell is None:
            ell = self.cache_len
        ell = max(int(ell), 1)
        return parallelism_budget(self.cfg, self.hardware, self.gran,
                                  self.batch, ell, eps, routing)

    # ------------------------------------------------------------------
    def prefill(self, tokens: Array) -> Array:
        """tokens: (b, prompt_len).  Returns last-position logits.

        ``self.last_hidden`` holds the (b, d) final-norm hidden state of
        the last prompt position — the state auxiliary head banks (MTP)
        propose from."""
        self._require_dense("prefill")
        logits, self.cache, hidden = _prefill_fn(self.params, self.cfg,
                                                 tokens, self.cache,
                                                 self.use_kernel)
        self.cache_len = int(tokens.shape[1])
        self.last_hidden = hidden[:, -1]
        return logits[:, -1]

    def decode_step(self, tokens: Array, advance: Optional[int] = None
                    ) -> Array:
        """One multi-position decode forward over N = tokens.shape[1]
        positions.  ``advance`` = how many of the N positions to commit to
        the cache (speculative decoding commits only accepted tokens);
        default commits all N."""
        self._require_dense("decode_step")
        logits, new_cache, _ = _decode_fn(self.params, self.cfg, tokens,
                                          self.cache, self.cache_len,
                                          self.use_kernel)
        n = tokens.shape[1]
        adv = n if advance is None else advance
        if adv > 0:
            self.cache = new_cache
            self.cache_len = self.cache_len + int(adv)
        return logits

    def peek_step(self, tokens: Array) -> Tuple[Array, Dict, Array]:
        """Decode forward WITHOUT committing (verification forwards).
        Returns (logits, new_cache, hidden)."""
        self._require_dense("peek_step")
        return _decode_fn(self.params, self.cfg, tokens, self.cache,
                          self.cache_len, self.use_kernel)

    def commit(self, new_cache: Dict, n_accepted) -> None:
        self._require_dense("commit")
        self.cache = new_cache
        self.cache_len = self.cache_len + int(n_accepted)

    # ------------------------------------------------------------------
    # Slotted multi-request mode (repro.serving.scheduler).  Each batch
    # row is an independent cache slot at its own sequence length; the
    # scheduler multiplexes requests over slots and the NFP budget over
    # the per-forward positions.
    # ------------------------------------------------------------------
    def _row_mask(self, rows, like: Array) -> Array:
        m = jnp.zeros((self.batch,), bool).at[jnp.asarray(rows)].set(True)
        return m.reshape((1, self.batch) + (1,) * (like.ndim - 2))

    def _set_slot_len(self, slot: int, value: int) -> None:
        """Update one slot's committed length on device AND in the host
        mirror — ``value`` is always host-known (a prompt length or a
        cached-prefix length), so the mirror costs nothing."""
        self.slot_lens = self.slot_lens.at[slot].set(value)
        self.slot_lens_host[slot] = int(value)

    def prefill_bucket(self, p: int) -> int:
        """Power-of-two prompt-length bucket (floor 8, ceiling max_len):
        bucketed prefill compiles once per BUCKET, not once per distinct
        prompt length."""
        b = 8
        while b < p:
            b *= 2
        return min(b, self.max_len)

    def _needs_exact_prefill(self) -> bool:
        """SSM / hybrid segments carry a recurrent state that would
        absorb the bucket's tail padding — those archs prefill at exact
        prompt lengths (still batched across equal-length prompts)."""
        return any(kind != LAYER_ATTN for kind, _ in make_segments(self.cfg))

    def prefill_slots(self, prompts: Dict[int, Array],
                      reserve: Optional[Dict[int, int]] = None
                      ) -> Dict[int, Tuple[Array, Array]]:
        """Bucketed multi-slot batched prefill: fill MANY cache slots in
        one forward.  ``prompts``: {slot: (p,) tokens}.

        Prompts are right-padded to a shared power-of-two length bucket
        (masked by causality: pad positions sit AFTER each prompt, so no
        prompt position attends to them; their junk KV lands beyond
        ``slot_lens`` where the decode mask never reads it before the
        next forward overwrites it).  One compile per bucket replaces the
        per-admission recompile storm of prefilling each distinct prompt
        length separately — and one forward admits the whole group.

        On a PAGED engine, ``reserve`` caps each slot's block-table
        reservation to {slot: prompt + max_tokens + headroom} positions
        (default: the full ``max_len``), and admissions whose prompt
        prefix is prefix-cache resident skip the prefill compute for the
        shared blocks — only the divergent suffix runs, as a per-row
        offset decode-shape forward (see ``_prefill_slots_paged``).

        Returns {slot: (last-prompt-position logits, hidden)}.
        """
        lens = {s: int(jnp.shape(p)[0]) for s, p in prompts.items()}
        for s, p in lens.items():
            if p < 1:
                raise ValueError(f"slot {s}: empty prompt")
            if p > self.max_len:
                raise ValueError(
                    f"slot {s}: prompt of {p} tokens exceeds the engine's "
                    f"max_len={self.max_len}; it cannot be prefilled "
                    "(admission should have rejected it)")
        if self.manager is not None:
            return self._prefill_slots_paged(prompts, lens, reserve or {})
        groups: List[Tuple[int, List[int]]]
        if self._needs_exact_prefill():
            by_len: Dict[int, List[int]] = {}
            for s, p in lens.items():
                by_len.setdefault(p, []).append(s)
            groups = [(p, rows) for p, rows in sorted(by_len.items())]
        else:
            groups = [(self.prefill_bucket(max(lens.values())),
                       list(prompts))]
        out: Dict[int, Tuple[Array, Array]] = {}
        for width, rows in groups:
            toks = np.zeros((self.batch, width), np.int32)
            for s in rows:
                toks[s, :lens[s]] = np.asarray(prompts[s], np.int64)
            logits, new_cache, hidden = _prefill_fn(
                self.params, self.cfg, jnp.asarray(toks), self.cache,
                self.use_kernel)
            self.cache = jax.tree.map(
                lambda old, new: jnp.where(self._row_mask(rows, old),
                                           new, old),
                self.cache, new_cache)
            for s in rows:
                self._set_slot_len(s, lens[s])
                out[s] = (logits[s, lens[s] - 1], hidden[s, lens[s] - 1])
            self.prefill_log.append({"slots": sorted(rows),
                                     "bucket": width,
                                     "computed_tokens": sum(
                                         lens[s] for s in rows)})
        return out

    def _prefill_slots_paged(self, prompts: Dict[int, Array],
                             lens: Dict[int, int],
                             reserve: Dict[int, int]
                             ) -> Dict[int, Tuple[Array, Array]]:
        """Paged admission + prefill.

        Per slot: the BlockManager attaches prefix-cache-resident blocks
        (read-only, refcounted), performs the divergence-block
        copy-on-write when the reuse boundary falls inside a shared
        block, and eagerly allocates the rest of the reservation.  Then:

          - NO-HIT slots run the normal bucketed prefill against a dense
            SCRATCH cache sized to the bucket, and the fresh KV is
            scattered into their pool pages — the forward itself is
            byte-identical to the dense engine's.
          - HIT slots skip the shared prefix entirely: only the
            divergent suffix runs, as ONE shared decode-shape forward at
            per-row offsets (= each slot's cached length), writing
            straight into the pool.  This is where prefix caching turns
            into saved prefill compute.

        Full prompt blocks register in the prefix cache AFTERWARD (their
        KV is resident by then), so later admissions can hit them.
        """
        mgr = self.manager
        tok_host = {s: np.asarray(prompts[s], np.int64).ravel()
                    for s in prompts}
        plans = {}
        for s in sorted(prompts):
            r = min(int(reserve.get(s, self.max_len)), self.max_len)
            plans[s] = mgr.admit(s, tok_host[s].tolist(),
                                 max(r, lens[s]))
        self._bt_device = None                 # tables changed
        cows = [c for s in sorted(prompts) for c in plans[s].cow_copies]
        if cows:
            self.cache = _copy_pool_blocks(
                self.cache, jnp.asarray([c[0] for c in cows], jnp.int32),
                jnp.asarray([c[1] for c in cows], jnp.int32))
        full = sorted(s for s in prompts if plans[s].cached_len == 0)
        hits = sorted(s for s in prompts if plans[s].cached_len > 0)
        out: Dict[int, Tuple[Array, Array]] = {}
        bs = mgr.block_size
        if full:
            width = self.prefill_bucket(max(lens[s] for s in full))
            toks = np.zeros((self.batch, width), np.int32)
            for s in full:
                toks[s, :lens[s]] = tok_host[s]
            scratch = init_cache(self.cfg, self.batch, width)
            logits, scratch, hidden = _prefill_fn(
                self.params, self.cfg, jnp.asarray(toks), scratch,
                self.use_kernel)
            rows, cols, flats = [], [], []
            for s in full:
                pos = np.arange(lens[s])
                page = mgr.tables[s, pos // bs].astype(np.int64)
                rows.append(np.full(lens[s], s, np.int64))
                cols.append(pos)
                flats.append(page * bs + pos % bs)
            rows = np.concatenate(rows)
            cols = np.concatenate(cols)
            flats = np.concatenate(flats)
            # pad the scatter to a power-of-two bucket (compile reuse);
            # pad entries dump into the trash page
            m = 8
            while m < len(rows):
                m *= 2
            pad = m - len(rows)
            rows = np.pad(rows, (0, pad))
            cols = np.pad(cols, (0, pad))
            flats = np.pad(flats, (0, pad), constant_values=mgr.trash * bs)
            self.cache = _scatter_prefill(
                self.cache, scratch, jnp.asarray(flats, jnp.int32),
                jnp.asarray(rows, jnp.int32), jnp.asarray(cols, jnp.int32))
            for s in full:
                self._set_slot_len(s, lens[s])
                out[s] = (logits[s, lens[s] - 1], hidden[s, lens[s] - 1])
            self.prefill_log.append({"slots": full, "bucket": width,
                                     "cached_tokens": 0,
                                     "computed_tokens": sum(
                                         lens[s] for s in full)})
        if hits:
            suf = {s: lens[s] - plans[s].cached_len for s in hits}
            for s in hits:
                self._set_slot_len(s, plans[s].cached_len)
            width = self.prefill_bucket(max(suf.values()))
            toks = np.zeros((self.batch, width), np.int32)
            for s in hits:
                toks[s, :suf[s]] = tok_host[s][plans[s].cached_len:]
            logits, new_cache, hidden = _decode_paged_fn(
                self.params, self.cfg, jnp.asarray(toks), self.cache,
                self.slot_lens, self._device_tables(), self.use_kernel)
            # suffix KV is committed; rows outside the hit group wrote
            # past their own committed length (or into the trash page),
            # which no mask ever reads back
            self.cache = new_cache
            for s in hits:
                self._set_slot_len(s, lens[s])
                out[s] = (logits[s, suf[s] - 1], hidden[s, suf[s] - 1])
            self.prefill_log.append({
                "slots": hits, "bucket": width,
                "cached_tokens": sum(plans[s].cached_len for s in hits),
                "computed_tokens": sum(suf.values())})
        for s in sorted(prompts):
            mgr.register_prompt(s, tok_host[s].tolist())
        return out

    def prefill_slot(self, slot: int, prompt: Array) -> Array:
        """Prefill ONE cache slot; thin wrapper over ``prefill_slots``."""
        (logits, _hidden) = self.prefill_slots({slot: prompt})[slot]
        return logits

    def decode_slots(self, tokens: Array) -> Tuple[Array, Dict, Array]:
        """Multi-position decode forward over ALL slots at their own
        cache lengths, WITHOUT committing.  tokens: (batch, n).
        Returns (logits, new_cache, hidden).

        With ``use_kernel=True`` the per-slot lengths ride the ragged
        Pallas decode-attention kernel's scalar-prefetch lane — one
        quantized launch for the whole mixed-length batch (on a paged
        engine, with the block tables as a second prefetch operand)."""
        if self.manager is not None:
            return _decode_paged_fn(self.params, self.cfg, tokens,
                                    self.cache, self.slot_lens,
                                    self._device_tables(), self.use_kernel)
        return _decode_fn(self.params, self.cfg, tokens, self.cache,
                          self.slot_lens, self.use_kernel)

    def commit_slots(self, new_cache: Dict, advances) -> None:
        """Commit per-slot: rows with advance > 0 take the new cache and
        bump their length; rows with 0 are untouched (inactive slots or
        fully-rejected blocks).  The row mask is built from the advances
        ON DEVICE — materializing it on the host would force a device
        sync every scheduler step.  ``advances`` must be HOST values
        (the adapters' accept counts always are): they also feed the
        ``slot_lens_host`` mirror the scheduler budgets against.

        A paged engine adopts the new pool wholesale: the forward's
        writes only ever touch pages the writing slot exclusively owns
        (COW guarantees refcount-1 at write time) or the trash page, and
        rows that advanced 0 only wrote past their committed length —
        positions every mask skips until a later forward overwrites
        them.  Per-row selection would therefore change nothing."""
        adv_host = np.asarray(advances, np.int64)
        adv = jnp.asarray(adv_host, jnp.int32)
        self.slot_lens_host = self.slot_lens_host + adv_host
        if self.manager is not None:
            self.cache = new_cache
            self.slot_lens = self.slot_lens + adv
            return
        keep = adv > 0                               # (batch,) on device
        self.cache = jax.tree.map(
            lambda old, new: jnp.where(
                keep.reshape((1, self.batch) + (1,) * (old.ndim - 2)),
                new, old),
            self.cache, new_cache)
        self.slot_lens = self.slot_lens + adv

    def release_slot(self, slot: int) -> None:
        if self.manager is not None:
            self.manager.release(slot)
            self._bt_device = None             # tables changed
        self._set_slot_len(slot, 0)

    def preempt_slot(self, slot: int) -> None:
        """Evict a slot mid-stream (scheduler preemption): its paged
        blocks return to the pool — except prefix-cache-resident ones,
        which stay hit-able so the recompute-on-resume prefill can skip
        them — and the row's committed length zeroes.  The evicted KV is
        recomputed at re-admission from the request's host-side context,
        so no device state needs saving."""
        if self.manager is not None:
            self.manager.preempt(slot)
            self._bt_device = None             # tables changed
        self.preempted_slots += 1
        self._set_slot_len(slot, 0)

    # ------------------------------------------------------------------
    def greedy_generate(self, prompt: Array, steps: int) -> Array:
        """Plain autoregressive baseline (N=1 per forward)."""
        logits = self.prefill(prompt)
        last = jnp.argmax(logits, axis=-1)[:, None]
        out = [last]
        for _ in range(steps - 1):
            logits = self.decode_step(last)
            last = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(last)
        return jnp.concatenate(out, axis=1)
