"""Multi-position decode engine.

The engine executes the paper's abstraction directly: a decode forward
that processes N positions (Eq. 2) over a pre-allocated cache.  One
compiled executable serves every step at a given N (cache_len is a traced
scalar), matching the bucketed-compile discipline of TPU serving stacks.

The NFP budget (core.parallelism_budget) tells algorithm drivers
(speculative verification, diffusion block decode) how many positions are
near-free for the current arch x hardware x batch x context.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arch import ArchConfig
from repro.core.granularity import GranularitySpec
from repro.core.hardware import TPU_V5E, HardwareSpec
from repro.core.nfp import parallelism_budget
from repro.models.transformer import forward, init_cache

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def _prefill_fn(params, cfg: ArchConfig, tokens, cache, use_kernel=False):
    logits, cache, _, hidden = forward(params, cfg, {"tokens": tokens},
                                       mode="prefill", cache=cache,
                                       cache_len=0, use_kernel=use_kernel)
    return logits, cache, hidden


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def _decode_fn(params, cfg: ArchConfig, tokens, cache, cache_len,
               use_kernel=False):
    logits, cache, _, hidden = forward(params, cfg, {"tokens": tokens},
                                       mode="decode", cache=cache,
                                       cache_len=cache_len,
                                       use_kernel=use_kernel)
    return logits, cache, hidden


@dataclass
class DecodeEngine:
    cfg: ArchConfig
    params: Dict
    batch: int
    max_len: int
    hardware: HardwareSpec = TPU_V5E
    use_kernel: bool = False
    cache: Optional[Dict] = None
    cache_len: Array = field(default_factory=lambda: jnp.zeros((), jnp.int32))

    def __post_init__(self):
        if self.cache is None:
            self.cache = init_cache(self.cfg, self.batch, self.max_len)
        self.gran = GranularitySpec.for_backend(
            self.cfg.ffn.n_experts,
            head_dim=(self.cfg.attention.head_dim if self.cfg.attention
                      else 128))
        # per-slot cache lengths for the scheduler's slotted mode; the
        # single-request drivers keep using the scalar ``cache_len``
        self.slot_lens = jnp.zeros((self.batch,), jnp.int32)
        # (b, d) final-norm hidden of the last prefilled position (MTP
        # proposals read it); one entry per bucketed prefill forward
        self.last_hidden: Optional[Array] = None
        self.prefill_log: List[Dict] = []

    # ------------------------------------------------------------------
    def nfp_budget(self, eps: float = 0.2, routing: str = "balanced",
                   ell: Optional[int] = None) -> int:
        """Near-free position budget for the CURRENT state (Sec. 6)."""
        if ell is None:
            ell = int(self.cache_len)
        ell = max(int(ell), 1)
        return parallelism_budget(self.cfg, self.hardware, self.gran,
                                  self.batch, ell, eps, routing)

    # ------------------------------------------------------------------
    def prefill(self, tokens: Array) -> Array:
        """tokens: (b, prompt_len).  Returns last-position logits.

        ``self.last_hidden`` holds the (b, d) final-norm hidden state of
        the last prompt position — the state auxiliary head banks (MTP)
        propose from."""
        logits, self.cache, hidden = _prefill_fn(self.params, self.cfg,
                                                 tokens, self.cache,
                                                 self.use_kernel)
        self.cache_len = jnp.asarray(tokens.shape[1], jnp.int32)
        self.last_hidden = hidden[:, -1]
        return logits[:, -1]

    def decode_step(self, tokens: Array, advance: Optional[int] = None
                    ) -> Array:
        """One multi-position decode forward over N = tokens.shape[1]
        positions.  ``advance`` = how many of the N positions to commit to
        the cache (speculative decoding commits only accepted tokens);
        default commits all N."""
        logits, new_cache, _ = _decode_fn(self.params, self.cfg, tokens,
                                          self.cache, self.cache_len,
                                          self.use_kernel)
        n = tokens.shape[1]
        adv = n if advance is None else advance
        if adv > 0:
            self.cache = new_cache
            self.cache_len = self.cache_len + adv
        return logits

    def peek_step(self, tokens: Array) -> Tuple[Array, Dict, Array]:
        """Decode forward WITHOUT committing (verification forwards).
        Returns (logits, new_cache, hidden)."""
        return _decode_fn(self.params, self.cfg, tokens, self.cache,
                          self.cache_len, self.use_kernel)

    def commit(self, new_cache: Dict, n_accepted) -> None:
        self.cache = new_cache
        self.cache_len = self.cache_len + n_accepted

    # ------------------------------------------------------------------
    # Slotted multi-request mode (repro.serving.scheduler).  Each batch
    # row is an independent cache slot at its own sequence length; the
    # scheduler multiplexes requests over slots and the NFP budget over
    # the per-forward positions.
    # ------------------------------------------------------------------
    def _row_mask(self, rows, like: Array) -> Array:
        m = jnp.zeros((self.batch,), bool).at[jnp.asarray(rows)].set(True)
        return m.reshape((1, self.batch) + (1,) * (like.ndim - 2))

    def prefill_bucket(self, p: int) -> int:
        """Power-of-two prompt-length bucket (floor 8, ceiling max_len):
        bucketed prefill compiles once per BUCKET, not once per distinct
        prompt length."""
        b = 8
        while b < p:
            b *= 2
        return min(b, self.max_len)

    def _needs_exact_prefill(self) -> bool:
        """SSM / hybrid segments carry a recurrent state that would
        absorb the bucket's tail padding — those archs prefill at exact
        prompt lengths (still batched across equal-length prompts)."""
        from repro.core.arch import LAYER_ATTN
        from repro.models.transformer import make_segments
        return any(kind != LAYER_ATTN for kind, _ in make_segments(self.cfg))

    def prefill_slots(self, prompts: Dict[int, Array]
                      ) -> Dict[int, Tuple[Array, Array]]:
        """Bucketed multi-slot batched prefill: fill MANY cache slots in
        one forward.  ``prompts``: {slot: (p,) tokens}.

        Prompts are right-padded to a shared power-of-two length bucket
        (masked by causality: pad positions sit AFTER each prompt, so no
        prompt position attends to them; their junk KV lands beyond
        ``slot_lens`` where the decode mask never reads it before the
        next forward overwrites it).  One compile per bucket replaces the
        per-admission recompile storm of prefilling each distinct prompt
        length separately — and one forward admits the whole group.

        Returns {slot: (last-prompt-position logits, hidden)}.
        """
        lens = {s: int(jnp.shape(p)[0]) for s, p in prompts.items()}
        groups: List[Tuple[int, List[int]]]
        if self._needs_exact_prefill():
            by_len: Dict[int, List[int]] = {}
            for s, p in lens.items():
                by_len.setdefault(p, []).append(s)
            groups = [(p, rows) for p, rows in sorted(by_len.items())]
        else:
            groups = [(self.prefill_bucket(max(lens.values())),
                       list(prompts))]
        out: Dict[int, Tuple[Array, Array]] = {}
        for width, rows in groups:
            toks = np.zeros((self.batch, width), np.int32)
            for s in rows:
                toks[s, :lens[s]] = np.asarray(prompts[s], np.int64)
            logits, new_cache, hidden = _prefill_fn(
                self.params, self.cfg, jnp.asarray(toks), self.cache,
                self.use_kernel)
            self.cache = jax.tree.map(
                lambda old, new: jnp.where(self._row_mask(rows, old),
                                           new, old),
                self.cache, new_cache)
            for s in rows:
                self.slot_lens = self.slot_lens.at[s].set(lens[s])
                out[s] = (logits[s, lens[s] - 1], hidden[s, lens[s] - 1])
            self.prefill_log.append({"slots": sorted(rows),
                                     "bucket": width})
        return out

    def prefill_slot(self, slot: int, prompt: Array) -> Array:
        """Prefill ONE cache slot; thin wrapper over ``prefill_slots``."""
        (logits, _hidden) = self.prefill_slots({slot: prompt})[slot]
        return logits

    def decode_slots(self, tokens: Array) -> Tuple[Array, Dict, Array]:
        """Multi-position decode forward over ALL slots at their own
        cache lengths, WITHOUT committing.  tokens: (batch, n).
        Returns (logits, new_cache, hidden).

        With ``use_kernel=True`` the per-slot lengths ride the ragged
        Pallas decode-attention kernel's scalar-prefetch lane — one
        quantized launch for the whole mixed-length batch."""
        return _decode_fn(self.params, self.cfg, tokens, self.cache,
                          self.slot_lens, self.use_kernel)

    def commit_slots(self, new_cache: Dict, advances) -> None:
        """Commit per-slot: rows with advance > 0 take the new cache and
        bump their length; rows with 0 are untouched (inactive slots or
        fully-rejected blocks).  The row mask is built from the advances
        ON DEVICE — materializing it on the host would force a device
        sync every scheduler step."""
        adv = jnp.asarray(advances, jnp.int32)
        keep = adv > 0                               # (batch,) on device
        self.cache = jax.tree.map(
            lambda old, new: jnp.where(
                keep.reshape((1, self.batch) + (1,) * (old.ndim - 2)),
                new, old),
            self.cache, new_cache)
        self.slot_lens = self.slot_lens + adv

    def release_slot(self, slot: int) -> None:
        self.slot_lens = self.slot_lens.at[slot].set(0)

    # ------------------------------------------------------------------
    def greedy_generate(self, prompt: Array, steps: int) -> Array:
        """Plain autoregressive baseline (N=1 per forward)."""
        logits = self.prefill(prompt)
        last = jnp.argmax(logits, axis=-1)[:, None]
        out = [last]
        for _ in range(steps - 1):
            logits = self.decode_step(last)
            last = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(last)
        return jnp.concatenate(out, axis=1)
