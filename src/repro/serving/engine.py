"""Multi-position decode engine.

The engine executes the paper's abstraction directly: a decode forward
that processes N positions (Eq. 2) over a pre-allocated cache.  One
compiled executable serves every step at a given N (cache_len is a traced
scalar), matching the bucketed-compile discipline of TPU serving stacks.

The NFP budget (core.parallelism_budget) tells algorithm drivers
(speculative verification, diffusion block decode) how many positions are
near-free for the current arch x hardware x batch x context.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arch import ArchConfig
from repro.core.granularity import GranularitySpec
from repro.core.hardware import TPU_V5E, HardwareSpec
from repro.core.nfp import parallelism_budget
from repro.models.transformer import forward, init_cache

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def _prefill_fn(params, cfg: ArchConfig, tokens, cache, use_kernel=False):
    logits, cache, _ = forward(params, cfg, {"tokens": tokens},
                               mode="prefill", cache=cache, cache_len=0,
                               use_kernel=use_kernel)
    return logits, cache


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernel"))
def _decode_fn(params, cfg: ArchConfig, tokens, cache, cache_len,
               use_kernel=False):
    logits, cache, _ = forward(params, cfg, {"tokens": tokens},
                               mode="decode", cache=cache,
                               cache_len=cache_len, use_kernel=use_kernel)
    return logits, cache


@dataclass
class DecodeEngine:
    cfg: ArchConfig
    params: Dict
    batch: int
    max_len: int
    hardware: HardwareSpec = TPU_V5E
    use_kernel: bool = False
    cache: Optional[Dict] = None
    cache_len: Array = field(default_factory=lambda: jnp.zeros((), jnp.int32))

    def __post_init__(self):
        if self.cache is None:
            self.cache = init_cache(self.cfg, self.batch, self.max_len)
        self.gran = GranularitySpec.for_backend(
            self.cfg.ffn.n_experts,
            head_dim=(self.cfg.attention.head_dim if self.cfg.attention
                      else 128))
        # per-slot cache lengths for the scheduler's slotted mode; the
        # single-request drivers keep using the scalar ``cache_len``
        self.slot_lens = jnp.zeros((self.batch,), jnp.int32)

    # ------------------------------------------------------------------
    def nfp_budget(self, eps: float = 0.2, routing: str = "balanced",
                   ell: Optional[int] = None) -> int:
        """Near-free position budget for the CURRENT state (Sec. 6)."""
        if ell is None:
            ell = int(self.cache_len)
        ell = max(int(ell), 1)
        return parallelism_budget(self.cfg, self.hardware, self.gran,
                                  self.batch, ell, eps, routing)

    # ------------------------------------------------------------------
    def prefill(self, tokens: Array) -> Array:
        """tokens: (b, prompt_len).  Returns last-position logits."""
        logits, self.cache = _prefill_fn(self.params, self.cfg, tokens,
                                         self.cache, self.use_kernel)
        self.cache_len = jnp.asarray(tokens.shape[1], jnp.int32)
        return logits[:, -1]

    def decode_step(self, tokens: Array, advance: Optional[int] = None
                    ) -> Array:
        """One multi-position decode forward over N = tokens.shape[1]
        positions.  ``advance`` = how many of the N positions to commit to
        the cache (speculative decoding commits only accepted tokens);
        default commits all N."""
        logits, new_cache = _decode_fn(self.params, self.cfg, tokens,
                                       self.cache, self.cache_len,
                                       self.use_kernel)
        n = tokens.shape[1]
        adv = n if advance is None else advance
        if adv > 0:
            self.cache = new_cache
            self.cache_len = self.cache_len + adv
        return logits

    def peek_step(self, tokens: Array) -> Tuple[Array, Dict]:
        """Decode forward WITHOUT committing (verification forwards)."""
        return _decode_fn(self.params, self.cfg, tokens, self.cache,
                          self.cache_len, self.use_kernel)

    def commit(self, new_cache: Dict, n_accepted) -> None:
        self.cache = new_cache
        self.cache_len = self.cache_len + n_accepted

    # ------------------------------------------------------------------
    # Slotted multi-request mode (repro.serving.scheduler).  Each batch
    # row is an independent cache slot at its own sequence length; the
    # scheduler multiplexes requests over slots and the NFP budget over
    # the per-forward positions.
    # ------------------------------------------------------------------
    def _row_mask(self, rows, like: Array) -> Array:
        m = jnp.zeros((self.batch,), bool).at[jnp.asarray(rows)].set(True)
        return m.reshape((1, self.batch) + (1,) * (like.ndim - 2))

    def prefill_slot(self, slot: int, prompt: Array) -> Array:
        """Prefill ONE cache slot with a (p,) prompt; other slots keep
        their state.  Returns the slot's last-position logits."""
        toks = jnp.broadcast_to(jnp.asarray(prompt, jnp.int32)[None],
                                (self.batch, len(prompt)))
        logits, new_cache = _prefill_fn(self.params, self.cfg, toks,
                                        self.cache, self.use_kernel)
        self.cache = jax.tree.map(
            lambda old, new: jnp.where(self._row_mask([slot], old),
                                       new, old),
            self.cache, new_cache)
        self.slot_lens = self.slot_lens.at[slot].set(len(prompt))
        return logits[slot, -1]

    def decode_slots(self, tokens: Array) -> Tuple[Array, Dict]:
        """Multi-position decode forward over ALL slots at their own
        cache lengths, WITHOUT committing.  tokens: (batch, n).

        With ``use_kernel=True`` the per-slot lengths ride the ragged
        Pallas decode-attention kernel's scalar-prefetch lane — one
        quantized launch for the whole mixed-length batch."""
        return _decode_fn(self.params, self.cfg, tokens, self.cache,
                          self.slot_lens, self.use_kernel)

    def commit_slots(self, new_cache: Dict, advances) -> None:
        """Commit per-slot: rows with advance > 0 take the new cache and
        bump their length; rows with 0 are untouched (inactive slots or
        fully-rejected blocks)."""
        adv = jnp.asarray(advances, jnp.int32)
        mask_rows = [int(i) for i in np.nonzero(np.asarray(advances))[0]]
        if not mask_rows:
            return
        self.cache = jax.tree.map(
            lambda old, new: jnp.where(self._row_mask(mask_rows, old),
                                       new, old),
            self.cache, new_cache)
        self.slot_lens = self.slot_lens + adv

    def release_slot(self, slot: int) -> None:
        self.slot_lens = self.slot_lens.at[slot].set(0)

    # ------------------------------------------------------------------
    def greedy_generate(self, prompt: Array, steps: int) -> Array:
        """Plain autoregressive baseline (N=1 per forward)."""
        logits = self.prefill(prompt)
        last = jnp.argmax(logits, axis=-1)[:, None]
        out = [last]
        for _ in range(steps - 1):
            logits = self.decode_step(last)
            last = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(last)
        return jnp.concatenate(out, axis=1)
