"""Production serving launcher: multi-position decode with the NFP budget.

Single-request (algorithm drivers):
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --tiny \
      --algorithm speculative --tokens 48

Multi-request (budget-aware continuous batching):
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --tiny \
      --requests 8 --slots 4 --serve-mode speculative --tokens 32

Trace replay (production-shaped traffic on the simulated clock):
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --tiny \
      --trace pinned --requests 8 --slots 4 --serve-mode speculative

Loads (or random-inits) a model, builds the decode engine, selects the
parallelism level from the NFP principle for the current hardware +
batch + context, and serves generation — one request through a
parallel-decoding driver, or many through the ServingLoop scheduler
that splits the budget across concurrent requests.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.autotune import (BudgetController, calibrate_engine, load_table,
                            save_table, spec_fingerprint)
from repro.checkpoint import latest_step, restore
from repro.configs import get_config
from repro.core import get_hardware
from repro.models import init_model
from repro.serving import (DecodeEngine, DiffusionBlockDecoder,
                           MTPDecoder, PagedKVConfig, ServingLoop,
                           SpeculativeDecoder, init_mtp_heads)


def _single_request(args, cfg, params) -> None:
    eng = DecodeEngine(cfg, params, batch=args.batch, max_len=args.max_len,
                       hardware=get_hardware(args.hardware),
                       use_kernel=args.use_kernel)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    if args.algorithm == "greedy":
        out = np.asarray(eng.greedy_generate(prompt, args.tokens)[0])
        stats = {"tokens": args.tokens, "forwards": args.tokens}
    elif args.algorithm == "speculative":
        out, stats = SpeculativeDecoder(eng).generate(prompt, args.tokens)
    elif args.algorithm == "mtp":
        heads = init_mtp_heads(jax.random.PRNGKey(5), cfg.d_model,
                               cfg.vocab_size, n_heads=4)
        out, stats = MTPDecoder(eng, heads).generate(prompt, args.tokens)
    else:
        out, stats = DiffusionBlockDecoder(eng).generate(prompt, args.tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} algo={args.algorithm} "
          f"nfp_budget={eng.nfp_budget()}")
    print(f"generated {stats['tokens']} tokens in {dt:.2f}s "
          f"({stats.get('forwards', '?')} forwards, "
          f"{stats.get('tokens_per_forward', 1):.2f} tok/fwd)")
    print("tokens:", out[:32], "...")


def _calibration_controller(args, eng):
    """--calibration {run,load}: produce/load the calibration artifact
    and wrap it in a BudgetController for the serving loop."""
    key = spec_fingerprint(eng.cfg, eng.hardware, eng.gran,
                           (eng.use_kernel,), eng.batch, eps=0.2)
    if args.calibration == "run":
        table = calibrate_engine(eng, modes=(args.serve_mode,))
        save_table(table, args.calibration_path)
        print(f"calibration: swept {len(table.buckets())} context buckets "
              f"via {table.backend} backend -> {args.calibration_path} "
              f"(key {table.key})")
    else:
        table = load_table(args.calibration_path, expect_key=key)
        print(f"calibration: loaded {args.calibration_path} "
              f"({table.backend} backend, key {table.key})")
    for e in sorted(table.entries, key=lambda e: e.ell):
        if e.mode == args.serve_mode and e.use_kernel == eng.use_kernel:
            print(f"  L<={e.ell}: analytic={e.analytic_nmax} "
                  f"measured={e.measured_nmax} "
                  f"calibrated={e.calibrated_budget} "
                  f"over-prediction={e.overprediction:.2f}x "
                  f"(limit={e.limiting})")
    return BudgetController(table=table)


def _multi_request(args, cfg, params) -> None:
    paged = None
    if args.kv_block_size > 0:
        paged = PagedKVConfig(block_size=args.kv_block_size,
                              n_blocks=args.kv_blocks or None)
    eng = DecodeEngine(cfg, params, batch=args.slots, max_len=args.max_len,
                       hardware=get_hardware(args.hardware),
                       use_kernel=args.use_kernel, paged=paged)
    kwargs = {}
    if args.serve_mode == "mtp":
        kwargs["mtp_heads"] = init_mtp_heads(
            jax.random.PRNGKey(5), cfg.d_model, cfg.vocab_size, n_heads=4)
    controller = None
    if args.calibration != "off":
        controller = _calibration_controller(args, eng)
    loop = ServingLoop(eng, mode=args.serve_mode, controller=controller,
                       **kwargs)
    for i in range(args.requests):
        prompt = jax.random.randint(jax.random.PRNGKey(100 + i),
                                    (args.prompt_len,), 0, cfg.vocab_size)
        loop.submit(np.asarray(prompt), args.tokens)
    t0 = time.time()
    results = loop.run()
    dt = time.time() - t0
    s = loop.stats()
    # serving-time budget: run() released the slots, so read it from the
    # step log rather than recomputing at an empty cache
    budgets = [e["budget"] for e in loop.step_log] or [loop.budget()]
    print(f"arch={cfg.name} mode={args.serve_mode} slots={args.slots} "
          f"requests={args.requests} "
          f"nfp_budget={min(budgets)}..{max(budgets)}")
    print(f"served {s['requests']} requests / {s['tokens']} tokens in "
          f"{dt:.2f}s  ({s['forwards']} forwards, "
          f"{s['tokens_per_forward']:.2f} tok/fwd, "
          f"max {s['max_positions_per_forward']} positions/fwd)")
    print(f"throughput: {s['tokens'] / max(dt, 1e-9):.1f} tok/s")
    if controller is not None:
        cs = s["controller"]
        line = (f"budget control: analytic~{s['mean_budget_analytic']:.1f} "
                f"applied~{s['mean_budget']:.1f}")
        if "mean_budget_calibrated" in s:
            line += f" calibrated~{s['mean_budget_calibrated']:.1f}"
        if "max_latency_ratio" in s:
            line += (f"  latency ratio mean={s['mean_latency_ratio']:.2f} "
                     f"max={s['max_latency_ratio']:.2f}")
        line += (f"  (shrinks={cs['shrinks']} probes={cs['probes']} "
                 f"gated={cs['gated']})")
        print(line)
    if paged is not None:
        print(f"paged kv: block_size={s['kv_block_size']} "
              f"blocks={s['kv_blocks']} peak_used={s['kv_blocks_peak']}  "
              f"prefix: {s['prefix_hits']}/{s['prefix_lookups']} hits, "
              f"{s['prefill_positions_saved']} prefill positions saved, "
              f"{s['cow_copies']} cow, {s['prefix_evictions']} evictions")
    for rid, toks in list(results.items())[:4]:
        print(f"  req {rid}: {toks[:16]} ...")


def _trace_replay(args, cfg, params) -> None:
    """--trace: replay a loadgen trace (the pinned BENCH spec or a
    trace JSON file) through the ServingLoop on the roofline-simulated
    clock of the FULL-SIZE --arch config, with backpressure + SLO-
    priority admission and preemption enabled."""
    from repro.core import GranularitySpec
    from repro.core.simulate import decode_forward_cost
    from repro.loadgen import (Trace, generate_trace, pinned_spec,
                               replay_trace)
    from repro.serving import AdmissionConfig

    if args.trace == "pinned":
        n = args.requests if args.requests > 0 else 32
        trace = generate_trace(pinned_spec(n_requests=n))
    else:
        with open(args.trace) as f:
            trace = Trace.from_json(f.read())
    cfg_full = get_config(args.arch)
    gran = GranularitySpec.for_backend(
        cfg_full.ffn.n_experts,
        head_dim=(cfg_full.attention.head_dim if cfg_full.attention
                  else 128))
    hw = get_hardware(args.hardware)

    def clock(width: int, ell: int) -> float:
        return decode_forward_cost(cfg_full, args.slots, width,
                                   max(int(ell), 1), gran).time(hw)

    paged = None
    if args.kv_block_size > 0:
        paged = PagedKVConfig(block_size=args.kv_block_size,
                              n_blocks=args.kv_blocks or None)
    eng = DecodeEngine(cfg, params, batch=args.slots, max_len=args.max_len,
                       hardware=hw, use_kernel=args.use_kernel, paged=paged)
    kwargs = {}
    if args.serve_mode == "mtp":
        kwargs["mtp_heads"] = init_mtp_heads(
            jax.random.PRNGKey(5), cfg.d_model, cfg.vocab_size, n_heads=4)
    loop = ServingLoop(
        eng, mode=args.serve_mode, step_clock=clock,
        admission=AdmissionConfig(
            max_waiting=args.max_waiting or None, preemption=True),
        **kwargs)
    report = replay_trace(loop, trace)
    m = report["metrics"]
    s = report["serving"]
    print(f"arch={cfg.name} mode={args.serve_mode} slots={args.slots} "
          f"trace={trace.fingerprint()} ({len(trace.requests)} requests)")
    print(f"replayed {m['completed']} requests / {m['tokens']} tokens in "
          f"{report['makespan_s'] * 1e3:.2f} virtual ms "
          f"({report['clock']} clock)")
    if m["completed"]:
        print(f"ttft p50/p95/p99: {m['ttft_p50_s'] * 1e3:.2f} / "
              f"{m['ttft_p95_s'] * 1e3:.2f} / "
              f"{m['ttft_p99_s'] * 1e3:.2f} ms")
    print(f"goodput {m['goodput_tok_s']:.1f} tok/s of "
          f"{m['throughput_tok_s']:.1f} tok/s "
          f"(SLO attainment {m['slo_attainment']})")
    print(f"pressure: {s['preemptions']} preemptions, {s['resumes']} "
          f"resumes, {s['rejections']} rejections")
    for name, g in m["per_class"].items():
        print(f"  [{name}] {g['completed']}/{g['requests']} completed, "
              f"{g['rejected']} rejected, "
              f"attainment={g['slo_attainment']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--algorithm", default="speculative",
                    choices=["greedy", "speculative", "diffusion", "mtp"])
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--hardware", default="tpu_v5e")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas decode kernel (interpret on CPU)")
    ap.add_argument("--requests", type=int, default=0,
                    help="multi-request mode: serve N concurrent requests "
                         "through the budget-aware scheduler")
    ap.add_argument("--slots", type=int, default=4,
                    help="cache slots (max concurrent requests)")
    ap.add_argument("--serve-mode", default="greedy",
                    choices=["greedy", "speculative", "diffusion", "mtp"],
                    help="scheduler mode for --requests")
    ap.add_argument("--kv-block-size", type=int, default=0,
                    help="paged KV cache block size in positions "
                         "(0 = dense per-slot cache); must divide "
                         "--max-len; multi-request mode only")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="paged KV pool size in blocks (0 = dense-"
                         "parity default: slots * max_len / block)")
    ap.add_argument("--calibration", default="off",
                    choices=["off", "load", "run"],
                    help="empirical NFP calibration for the scheduler: "
                         "'run' sweeps T(N) on the engine (roofline-"
                         "simulator fallback without an accelerator), "
                         "saves the artifact, and serves with the "
                         "BudgetController; 'load' serves with a saved "
                         "artifact (refusing a stale spec hash)")
    ap.add_argument("--calibration-path", default="nfp_calibration.json",
                    help="calibration artifact path for --calibration")
    ap.add_argument("--trace", default=None,
                    help="replay a loadgen trace through the scheduler: "
                         "'pinned' (the BENCH spec, sized by --requests) "
                         "or a trace JSON path (repro.loadgen.Trace)")
    ap.add_argument("--max-waiting", type=int, default=0,
                    help="trace mode: bound the waiting queue "
                         "(backpressure; 0 = unbounded)")
    args = ap.parse_args()
    if args.trace is not None:
        cfg = get_config(args.arch, reduced=args.tiny)
        params = init_model(jax.random.PRNGKey(0), cfg)
        _trace_replay(args, cfg, params)
        return
    if args.kv_block_size > 0 and args.requests <= 0:
        ap.error("--kv-block-size serves the multi-request scheduler; "
                 "add --requests N")
    if args.kv_blocks > 0 and args.kv_block_size <= 0:
        ap.error("--kv-blocks sizes the paged pool; add --kv-block-size")
    if args.calibration != "off" and args.requests <= 0:
        ap.error("--calibration tunes the multi-request scheduler; "
                 "add --requests N")

    cfg = get_config(args.arch, reduced=args.tiny)
    params = init_model(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (restored, _) = restore(args.ckpt_dir, {"params": params})
        params = restored["params"]
        print(f"loaded checkpoint from {args.ckpt_dir}")

    if args.requests > 0:
        _multi_request(args, cfg, params)
    else:
        _single_request(args, cfg, params)


if __name__ == "__main__":
    main()
