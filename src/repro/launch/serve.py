"""Production serving launcher: multi-position decode with the NFP budget.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --tiny \
      --algorithm speculative --tokens 48

Loads (or random-inits) a model, builds the decode engine, selects the
parallelism level from the NFP principle for the current hardware +
batch + context, and serves batched greedy / speculative / diffusion
generation.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore
from repro.configs import get_config
from repro.core import TPU_V5E, get_hardware
from repro.models import init_model
from repro.serving import (DecodeEngine, DiffusionBlockDecoder,
                           SpeculativeDecoder)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--algorithm", default="speculative",
                    choices=["greedy", "speculative", "diffusion"])
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--hardware", default="tpu_v5e")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas decode kernel (interpret on CPU)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.tiny)
    params = init_model(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (restored, _) = restore(args.ckpt_dir, {"params": params})
        params = restored["params"]
        print(f"loaded checkpoint from {args.ckpt_dir}")

    eng = DecodeEngine(cfg, params, batch=args.batch, max_len=args.max_len,
                       hardware=get_hardware(args.hardware),
                       use_kernel=args.use_kernel)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    if args.algorithm == "greedy":
        out = np.asarray(eng.greedy_generate(prompt, args.tokens)[0])
        stats = {"tokens": args.tokens, "forwards": args.tokens}
    elif args.algorithm == "speculative":
        out, stats = SpeculativeDecoder(eng).generate(prompt, args.tokens)
    else:
        out, stats = DiffusionBlockDecoder(eng).generate(prompt, args.tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} algo={args.algorithm} "
          f"nfp_budget={eng.nfp_budget()}")
    print(f"generated {stats['tokens']} tokens in {dt:.2f}s "
          f"({stats.get('forwards', '?')} forwards, "
          f"{stats.get('tokens_per_forward', 1):.2f} tok/fwd)")
    print("tokens:", out[:32], "...")


if __name__ == "__main__":
    main()
