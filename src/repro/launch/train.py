"""Production training launcher: mesh + sharding + data + checkpoints +
restart-on-failure.

Single-host CPU demo:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm_3b --tiny \
      --steps 50

On a real fleet each host runs this same script under
`jax.distributed.initialize()` (see --coordinator); the mesh spans all
processes, the data pipeline shards by process_index, and a host failure
is handled by the launcher's restore-and-resume path (dist.elastic).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import get_config
from repro.data import DataConfig, make_pipeline
from repro.dist.elastic import StepWatchdog, elastic_mesh, run_with_restarts
from repro.dist.sharding import (batch_pspec, opt_pspecs, param_pspecs,
                                 shardings_from_pspecs)
from repro.launch.mesh import make_debug_mesh
from repro.models import init_model
from repro.training import AdamWConfig, init_opt_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-path", default=None,
                    help="binary shard dir; default synthetic")
    ap.add_argument("--coordinator", default=None,
                    help="host:port for jax.distributed (multi-host)")
    ap.add_argument("--sharding-policy", default="auto",
                    choices=["auto", "fsdp", "tp_only", "dp_only"])
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(coordinator_address=args.coordinator)

    cfg = get_config(args.arch, reduced=args.tiny)
    n_dev = jax.device_count()
    shape, axes = elastic_mesh(n_dev)
    mesh = (jax.make_mesh(shape, axes) if n_dev > 1
            else make_debug_mesh(1, 1))
    print(f"mesh {dict(zip(axes, shape)) if n_dev > 1 else '1-device'}  "
          f"arch {cfg.name}")

    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)
    p_ps = param_pspecs(params, mesh, policy=args.sharding_policy)
    o_ps = opt_pspecs(opt, p_ps, mesh)
    b_ps = {"tokens": batch_pspec(mesh, args.global_batch)}
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, n_micro=args.n_micro),
        in_shardings=(shardings_from_pspecs(p_ps, mesh),
                      shardings_from_pspecs(o_ps, mesh),
                      shardings_from_pspecs(b_ps, mesh)),
        out_shardings=(shardings_from_pspecs(p_ps, mesh),
                       shardings_from_pspecs(o_ps, mesh), None))

    data = make_pipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.global_batch, path=args.data_path),
        process_index=jax.process_index(),
        num_processes=jax.process_count())
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3)
    watchdog = StepWatchdog(deadline_s=600.0)

    state = {"params": params, "opt": opt}
    start = 0
    if latest_step(args.ckpt_dir) is not None:
        restored, meta = restore(args.ckpt_dir, state)
        state = restored
        start = int(meta.get("step", 0))
        print(f"resumed at step {start}")

    def one_step(step: int) -> None:
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state["params"], state["opt"], metrics = step_fn(
            state["params"], state["opt"], batch)
        dt = time.time() - t0
        watchdog.observe(dt)
        if step % 10 == 0:
            print(f"step {step:5d}  loss={float(metrics['loss']):.4f}  "
                  f"lr={float(metrics['lr']):.2e}  {dt:.2f}s")
        if step and step % args.ckpt_every == 0:
            ckpt.save(step, state, {"step": step})

    def restore_fn() -> int:
        restored, meta = restore(args.ckpt_dir, state)
        state.update(restored)
        return int(meta.get("step", 0))

    run_with_restarts(one_step, start, args.steps, restore_fn)
    ckpt.save(args.steps, state, {"step": args.steps})
    ckpt.wait()
    print("training complete; checkpoint committed")


if __name__ == "__main__":
    main()
