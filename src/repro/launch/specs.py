"""input_specs: ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, zero allocation — params/optimizer/cache
structures come from jax.eval_shape over the real init functions, so the
dry run lowers exactly the production step functions.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.arch import ArchConfig, ShapeSpec
from repro.core.granularity import round_up
from repro.dist.sharding import (batch_pspec, cache_pspecs, mesh_axes,
                                 opt_pspecs, param_pspecs)
from repro.models.transformer import forward, init_cache, init_model
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step

SDS = jax.ShapeDtypeStruct


def params_abstract(cfg: ArchConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(init_model, cfg=cfg), key)


def opt_abstract(params_sds):
    return jax.eval_shape(init_opt_state, params_sds)


def cache_abstract(cfg: ArchConfig, batch: int, max_len: int,
                   swa_ring: bool = False):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_len,
                          swa_ring=swa_ring))


def _dp_axes(mesh: Mesh):
    fsdp, _ = mesh_axes(mesh)
    return fsdp if isinstance(fsdp, tuple) else (fsdp,)


def _batch_like_pspec(mesh: Mesh, b: int, extra_dims: int) -> P:
    bdim = batch_pspec(mesh, b)[0]   # tokens spec is (bdim, None)
    return P(bdim, *([None] * extra_dims))


# ===========================================================================
# Cell builders: each returns (fn, args, in_pspecs, out_pspecs)
# ===========================================================================

REMAT_FRACTION_OPT = {
    # perf iteration #3: dense trainers afford saving layers outright
    "phi3-medium-14b": 0.25, "stablelm-3b": 0.5, "starcoder2-3b": 0.5,
    "phi-3-vision-4.2b": 0.5, "minicpm3-4b": 0.5,
}

# perf iteration (zamba2): sub-2B models replicate and train pure-DP over
# all 256 chips — no per-layer TP collectives at all, grads all-reduce once.
DP_ONLY_OPT = {"zamba2-1.2b", "whisper-tiny"}


def _opt_policy(cfg: ArchConfig) -> str:
    if cfg.name in DP_ONLY_OPT:
        return "dp_only"
    # MoE under TP-only forces per-layer (tokens, d_model) psum combines
    # after the f-sharded expert GEMMs — measured 4x collective REGRESSION
    # on granite (EXPERIMENTS.md §Perf iteration log); keep 2D FSDP there.
    if cfg.ffn.kind == "moe":
        return "fsdp"
    return "auto"


def train_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
               n_micro: int = 4, remat=True, variant: str = "baseline"):
    dp_only = variant == "opt" and _opt_policy(cfg) == "dp_only"
    if variant == "opt":
        remat = REMAT_FRACTION_OPT.get(cfg.name, 1.0)
    if dp_only:
        n_micro = 1            # full batch spreads over all 256 chips
    mb = shape.global_batch // n_micro
    if shape.global_batch % n_micro:
        raise ValueError(
            f"global_batch {shape.global_batch} is not divisible by "
            f"n_micro={n_micro}")
    lead = () if n_micro == 1 else (n_micro,)
    lead_ps = () if n_micro == 1 else (None,)
    tokens = SDS((*lead, mb, shape.seq_len), jnp.int32)
    batch: Dict[str, Any] = {"tokens": tokens}
    bp = batch_pspec(mesh, mb, include_model=dp_only)
    batch_ps: Dict[str, Any] = {"tokens": P(*lead_ps, *bp)}
    if cfg.family == "vlm":
        batch["embeds"] = SDS((*lead, mb, shape.seq_len, cfg.d_model),
                              jnp.bfloat16)
        batch_ps["embeds"] = P(*lead_ps, *bp, None)
    if cfg.encoder is not None:
        batch["frames"] = SDS((*lead, mb, cfg.encoder.n_frames,
                               cfg.d_model), jnp.bfloat16)
        batch_ps["frames"] = P(*lead_ps, *bp, None)

    params = params_abstract(cfg)
    opt = opt_abstract(params)
    policy = _opt_policy(cfg) if variant == "opt" else "fsdp"
    p_ps = param_pspecs(params, mesh, policy=policy)
    o_ps = (opt_pspecs(opt, p_ps, mesh) if variant == "opt"
            else opt_pspecs(opt, p_ps))
    opt_cfg = AdamWConfig()
    fn = make_train_step(cfg, opt_cfg, n_micro=n_micro, remat=remat)
    args = (params, opt, batch)
    in_ps = (p_ps, o_ps, batch_ps)
    out_ps = (p_ps, o_ps, None)
    return fn, args, in_ps, out_ps


def prefill_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                 variant: str = "baseline"):
    b, s = shape.global_batch, shape.seq_len
    buf = round_up(s, 256) if variant == "opt" else s
    tokens = SDS((b, s), jnp.int32)
    cache = cache_abstract(cfg, b, buf)
    inputs: Dict[str, Any] = {"tokens": tokens}
    in_extra_ps: Dict[str, Any] = {"tokens": batch_pspec(mesh, b)}
    if cfg.family == "vlm":
        inputs = {"embeds": SDS((b, s, cfg.d_model), jnp.bfloat16),
                  "tokens": tokens}
        in_extra_ps["embeds"] = _batch_like_pspec(mesh, b, 2)
    if cfg.encoder is not None:
        inputs["frames"] = SDS((b, cfg.encoder.n_frames, cfg.d_model),
                               jnp.bfloat16)
        in_extra_ps["frames"] = _batch_like_pspec(mesh, b, 2)

    def fn(params, inp, cache):
        if "embeds" in inp:
            fwd_in = {"embeds": inp["embeds"]}
        else:
            fwd_in = {"tokens": inp["tokens"]}
        if "frames" in inp:
            fwd_in["frames"] = inp["frames"]
        logits, new_cache, _, _ = forward(params, cfg, fwd_in, mode="prefill",
                                          cache=cache, cache_len=0)
        return logits[:, -1], new_cache

    params = params_abstract(cfg)
    # dp_only is a TRAIN mapping (grads all-reduce once); for prefill the
    # replicated-weights layout measured a 46x collective regression on
    # zamba2 — use the auto (tp/fsdp) policy here.
    policy = ("auto" if _opt_policy(cfg) == "dp_only" else _opt_policy(cfg))         if variant == "opt" else "fsdp"
    # prefill keeps head-mode cache: seq-sharding the cache during prefill
    # costs one full-KV reshard (measured +78 GB on granite) — in serving
    # that reshard happens ONCE per request at the prefill->decode
    # transition and amortizes over the decode phase (EXPERIMENTS §Perf).
    cmode = "head"
    p_ps = param_pspecs(params, mesh, policy=policy)
    c_ps = cache_pspecs(cache, mesh, b, mode=cmode)
    args = (params, inputs, cache)
    in_ps = (p_ps, in_extra_ps, c_ps)
    out_ps = (None, c_ps)
    return fn, args, in_ps, out_ps


def decode_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                n_positions: int = 1, variant: str = "baseline"):
    """serve_step: n_positions new tokens against a cache of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    max_len = s + n_positions
    swa_ring = (variant == "opt" and cfg.attention is not None
                and cfg.attention.kind == "swa")
    if variant == "opt":
        # sequence-sharded cache needs a tp-divisible buffer
        max_len = round_up(max_len, 256)
    tokens = SDS((b, n_positions), jnp.int32)
    cache = cache_abstract(cfg, b, max_len, swa_ring=swa_ring)
    cache_len = SDS((), jnp.int32)
    inputs: Dict[str, Any] = {"tokens": tokens}
    in_extra_ps: Dict[str, Any] = {"tokens": batch_pspec(mesh, b)}
    if cfg.family == "vlm":
        inputs = {"embeds": SDS((b, n_positions, cfg.d_model), jnp.bfloat16),
                  "tokens": tokens}
        in_extra_ps["embeds"] = _batch_like_pspec(mesh, b, 2)
    if cfg.encoder is not None:
        inputs["frames"] = SDS((b, cfg.encoder.n_frames, cfg.d_model),
                               jnp.bfloat16)
        in_extra_ps["frames"] = _batch_like_pspec(mesh, b, 2)

    def fn(params, inp, cache, cache_len):
        if "embeds" in inp:
            fwd_in = {"embeds": inp["embeds"]}
        else:
            fwd_in = {"tokens": inp["tokens"]}
        if "frames" in inp:
            fwd_in["frames"] = inp["frames"]
        logits, new_cache, _, _ = forward(params, cfg, fwd_in, mode="decode",
                                          cache=cache, cache_len=cache_len,
                                          swa_ring=swa_ring)
        return logits, new_cache

    params = params_abstract(cfg)
    policy = _opt_policy(cfg) if variant == "opt" else "fsdp"
    cmode = "seq" if variant == "opt" else "head"
    p_ps = param_pspecs(params, mesh, policy=policy)
    c_ps = cache_pspecs(cache, mesh, b, mode=cmode)
    args = (params, inputs, cache, cache_len)
    in_ps = (p_ps, in_extra_ps, c_ps, P())
    out_ps = (None, c_ps)
    return fn, args, in_ps, out_ps


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
               n_micro: int = 4, decode_positions: int = 1,
               variant: str = "baseline"):
    if shape.mode == "train":
        return train_cell(cfg, shape, mesh, n_micro=n_micro,
                          variant=variant)
    if shape.mode == "prefill":
        return prefill_cell(cfg, shape, mesh, variant=variant)
    return decode_cell(cfg, shape, mesh, n_positions=decode_positions,
                       variant=variant)


def to_shardings(pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else
        (None if s is None else NamedSharding(mesh, P())),
        pspecs, is_leaf=lambda x: isinstance(x, P) or x is None)
