import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first initialization.  REPRO_DRYRUN_DEVICES overrides for fast
# shakeout runs (still before jax import).
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCH_IDS, get_config          # noqa: E402
from repro.core.arch import LM_SHAPES, shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402
from repro.launch.specs import build_cell, to_shardings  # noqa: E402

# ---------------------------------------------------------------------------
# Collective-traffic extraction from post-SPMD HLO (per-device shapes).
# Operand bytes per op kind (brief: "sum operand sizes"):
#   all-reduce / all-to-all / collective-permute: operand == result size
#   all-gather:     operand = result / group_size
#   reduce-scatter: operand = result * group_size
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|(?P<shape>[a-z0-9]+\[[0-9,]*\]))\S*\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)\b")
_TUPLE_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*"
                            r"\([^)]*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"\bwhile\(.*condition=%?([\w\.\-]+),\s*"
                       r"body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1.0
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo_text: str):
    """Segment HLO text into {computation_name: [lines]}; 'ENTRY' marked."""
    comps, cur, name, entry = {}, [], None, None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMP_START_RE.match(line)
        if m and not line.startswith(" "):
            name = m.group(2)
            if m.group(1):
                entry = name
            comps[name] = cur = []
        elif name is not None:
            cur.append(stripped)
    return comps, entry


def _line_collective(line):
    m = _COLL_RE.search(line)
    if not m:
        return None
    op = m.group("op")
    shapes = _TUPLE_SHAPE_RE.findall(line.split(" " + op, 1)[0])
    size = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
    gm = _GROUP_RE.search(line)
    group = len(gm.group(1).split(",")) if gm else 1
    if op == "all-gather":
        size = size / max(group, 1)           # operand = result / group
    elif op == "reduce-scatter":
        size = size * max(group, 1)           # operand = result * group
    return op, size


def collective_bytes(hlo_text: str):
    """Per-device collective operand bytes with while-loop trip-count
    accounting: a scan's per-layer collectives are multiplied by the
    loop's trip count (parsed from the loop condition's constant), and
    nesting (microbatch scan over layer scan) composes multiplicatively.
    """
    comps, entry = _split_computations(hlo_text)
    # per-computation raw collective totals + while edges
    raw = {}
    edges = {}          # comp -> list[(body, trip)]
    for name, lines in comps.items():
        totals = {}
        whiles = []
        for line in lines:
            lc = _line_collective(line)
            if lc:
                totals[lc[0]] = totals.get(lc[0], 0.0) + lc[1]
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                consts = [int(c) for c in
                          _CONST_RE.findall("\n".join(comps.get(cond, [])))]
                trip = max(consts) if consts else 1
                whiles.append((body, max(trip, 1)))
        raw[name] = totals
        edges[name] = whiles

    # propagate execution multipliers from ENTRY through while nesting
    mult = dict.fromkeys(comps, 0.0)
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:
        return ({k: 0.0 for k in ("all-reduce", "all-gather",
                                  "reduce-scatter", "all-to-all",
                                  "collective-permute")}, {})
    mult[entry] = 1.0
    frontier = [entry]
    seen = set()
    while frontier:
        c = frontier.pop()
        if c in seen:
            continue
        seen.add(c)
        for body, trip in edges.get(c, []):
            if body in mult:
                mult[body] += mult[c] * trip
                frontier.append(body)

    totals = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
              "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(totals, 0)
    for name, t in raw.items():
        m = mult.get(name, 0.0) or (1.0 if name == entry else 0.0)
        # collectives in computations never reached from entry (e.g. called
        # subcomputations we did not model) count once
        if m == 0.0 and t:
            m = 1.0
        for op, size in t.items():
            totals[op] += size * m
            counts[op] += 1
    return totals, counts


# ---------------------------------------------------------------------------

def arch_n_micro(arch: str) -> int:
    # larger accumulation for the biggest models bounds live activations
    return {"mixtral_8x22b": 8, "phi3_medium_14b": 8}.get(arch, 4)


def run_cell(arch: str, shape, multi_pod: bool, out_dir: str,
             decode_positions: int = 1, force: bool = False,
             n_micro_override=None, tag: str = "", variant: str = "baseline"):
    mesh_name = "multipod" if multi_pod else "singlepod"
    if variant != "baseline" and not tag:
        tag = f"__{variant}"
    cell_id = f"{arch}__{shape.name}__{mesh_name}{tag}"
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            print(f"[skip] {cell_id} (cached)")
            return rec
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
           "seq_len": shape.seq_len, "global_batch": shape.global_batch,
           "mode": shape.mode}
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(path, rec)
        print(f"[skip] {cell_id}: {why}")
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_micro = n_micro_override or arch_n_micro(arch)
        fn, args, in_ps, out_ps = build_cell(
            cfg, shape, mesh, n_micro=n_micro,
            decode_positions=decode_positions, variant=variant)
        jitted = jax.jit(fn, in_shardings=to_shardings(in_ps, mesh),
                         out_shardings=to_shardings(out_ps, mesh))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        text = compiled.as_text()
        coll, coll_counts = collective_bytes(text)
        rec.update(
            status="ok",
            variant=variant,
            decode_positions=decode_positions,
            n_micro=n_micro,
            n_devices=mesh.devices.size,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None) or (
                    (getattr(mem, "argument_size_in_bytes", 0) or 0)
                    + (getattr(mem, "temp_size_in_bytes", 0) or 0)),
            },
            cost={
                "flops": cost.get("flops"),
                "bytes_accessed": cost.get("bytes accessed"),
                "transcendentals": cost.get("transcendentals"),
            },
            collective_bytes=coll,
            collective_counts=coll_counts,
            params=cfg.param_count(),
            params_active=cfg.param_count(active_only=True),
        )
        print(f"[ok]   {cell_id}  compile={t_compile:.0f}s "
              f"flops={cost.get('flops', 0):.3g} "
              f"peak={rec['memory']['peak_bytes']}")
    except Exception as e:                                  # noqa: BLE001
        rec.update(status="error", error=str(e)[:2000],
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] {cell_id}: {e}")
    rec["wall_s"] = round(time.time() - t0, 1)
    _write(path, rec)
    return rec


def _write(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--decode-positions", type=int, default=1)
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt"])
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = (LM_SHAPES if args.shape == "all"
              else [s for s in LM_SHAPES if s.name == args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out,
                               decode_positions=args.decode_positions,
                               force=args.force, variant=args.variant)
                s = rec["status"]
                n_ok += s == "ok"
                n_fail += s == "error"
                n_skip += s == "skipped"
    print(f"\ndone: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
