"""Production meshes.

Functions (not module-level constants) so importing this module never
touches jax device state — the 512-placeholder-device dry run must set
XLA_FLAGS before jax initializes.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod:  2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1, model: int = 1):
    """Tiny mesh for CPU tests."""
    return jax.make_mesh((max(n_devices // model, 1), model),
                         ("data", "model"))
