"""Pure-jnp oracle for the grouped expert FFN (no padding, no blocking)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def grouped_ffn_ref(x_sorted, params: Dict, group_sizes,
                    activation: str = "swiglu"):
    """Dense per-expert oracle: every row is run through its expert's FFN
    selected by masking — O(M*E) compute, exact semantics."""
    m, d = x_sorted.shape
    e = group_sizes.shape[0]
    expert_of_row = jnp.searchsorted(jnp.cumsum(group_sizes),
                                     jnp.arange(m), side="right")
    out = jnp.zeros((m, d), jnp.float32)
    for ei in range(e):
        sel = (expert_of_row == ei)[:, None]
        xf = x_sorted.astype(jnp.float32)
        up = xf @ params["w_up"][ei].astype(jnp.float32)
        if activation == "swiglu":
            gate = xf @ params["w_gate"][ei].astype(jnp.float32)
            h = jax.nn.silu(gate) * up
        else:
            h = jax.nn.gelu(up)
        o = h @ params["w_down"][ei].astype(jnp.float32)
        out = jnp.where(sel, o, out)
    return out.astype(x_sorted.dtype)
