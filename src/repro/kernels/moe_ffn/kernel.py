"""Pallas TPU fused grouped-GEMM MoE FFN (expert-token block-aligned).

This is the TPU re-derivation of the vLLM/SGLang fused-MoE mechanism the
paper inspects (App. E): tokens sorted by expert are padded to
``token_block`` rows (BLOCK_SIZE_M analogue = M_moe), and each grid step
runs one (token_block x d_model) tile through its expert's gate/up/down
weights — the expert id per block comes from scalar-prefetched metadata,
so the weight BlockSpec index_map is data-dependent exactly like the GPU
kernels' expert_ids lookup.

Grid: (n_blocks, n_f_tiles).  f (expert d_ff) is tiled so one weight tile
fits VMEM even for mixtral-sized experts; the fp32 output accumulates
across f tiles in scratch.  Blocks beyond the dynamic padded token count
are skipped with @pl.when — the TPU analogue of the GPU's dynamic grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_kernel(block_expert_ref, block_valid_ref,
                x_ref, *refs,
                activation: str, n_f_tiles: int):
    # refs is (wg, wu, wd, o, acc) for swiglu and (wu, wd, o, acc) for
    # gelu — a gated activation is the ONLY reason to stream a gate
    # tile; the gelu grid must not pay a second up-projection's DMA.
    if activation == "swiglu":
        wg_ref, wu_ref, wd_ref, o_ref, acc_ref = refs
    else:
        wu_ref, wd_ref, o_ref, acc_ref = refs
    jf = pl.program_id(1)

    @pl.when(jf == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(0)
    valid = block_valid_ref[i] > 0

    @pl.when(valid)
    def _compute():
        x = x_ref[...].astype(jnp.float32)                    # (tb, d)
        wu = wu_ref[0].astype(jnp.float32)                    # (d, ft)
        up = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        if activation == "swiglu":
            wg = wg_ref[0].astype(jnp.float32)
            gate = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
            h = jax.nn.silu(gate) * up
        else:
            h = jax.nn.gelu(up)
        wd = wd_ref[0].astype(jnp.float32)                    # (ft, d)
        acc_ref[...] += jax.lax.dot_general(h, wd, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32)

    @pl.when(jf == n_f_tiles - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def moe_ffn_pallas(x_padded, w_gate, w_up, w_down, block_expert, block_valid,
                   *, token_block: int, f_tile: int, activation: str,
                   interpret: bool = False):
    """x_padded: (m_pad, d); w_*: (E, d, f) / (E, f, d);
    block_expert/block_valid: (n_blocks,) i32 scalar-prefetch.
    ``w_gate`` may be None for non-gated activations — the gate operand
    is then dropped from the spec list entirely, so the grid streams one
    up-projection tile per step instead of two."""
    m_pad, d = x_padded.shape
    e, _, f = w_up.shape
    n_blocks = m_pad // token_block
    n_f_tiles = f // f_tile
    grid = (n_blocks, n_f_tiles)

    kernel = functools.partial(_moe_kernel, activation=activation,
                               n_f_tiles=n_f_tiles)
    expert_spec = pl.BlockSpec(
        (1, d, f_tile), lambda i, j, be, bv: (be[i], 0, j))
    in_specs = [pl.BlockSpec((token_block, d), lambda i, j, be, bv: (i, 0))]
    operands = [x_padded]
    if activation == "swiglu":
        in_specs.append(expert_spec)
        operands.append(w_gate)
    in_specs += [
        expert_spec,
        pl.BlockSpec((1, f_tile, d), lambda i, j, be, bv: (be[i], j, 0)),
    ]
    operands += [w_up, w_down]

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((token_block, d),
                                   lambda i, j, be, bv: (i, 0)),
            scratch_shapes=[pltpu.VMEM((token_block, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, d), x_padded.dtype),
        interpret=interpret,
    )(block_expert, block_valid, *operands)
