"""jit'd wrapper: moe_align_block_size (TPU edition) + fused grouped FFN.

``align_block_size`` is the faithful port of the mechanism in paper
Tables 3-7: routed token counts per expert are padded up to
``token_block`` (BLOCK_SIZE_M analogue), slots are laid out contiguously
per expert, and per-block expert ids + validity flags are produced for
the kernel's scalar-prefetch metadata.  The static allocation bound is
vLLM's own ``numel + E*(block-1)`` (Table 5), rounded to a block multiple.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.granularity import round_up, select_token_block
from repro.kernels.moe_ffn.kernel import moe_ffn_pallas


def align_block_size(expert_of_sorted: jnp.ndarray, group_sizes: jnp.ndarray,
                     n_experts: int, token_block: int,
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Returns (slot_of_sorted (M,), block_expert (n_blocks,),
    block_valid (n_blocks,), m_pad_max).

    slot_of_sorted maps each sorted token row to its padded slot.
    """
    m = expert_of_sorted.shape[0]
    m_pad_max = round_up(m + n_experts * (token_block - 1), token_block)
    n_blocks = m_pad_max // token_block

    padded_counts = ((group_sizes + token_block - 1) // token_block
                     ) * token_block
    pad_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(padded_counts)[:-1].astype(jnp.int32)])
    grp_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)])
    rank = jnp.arange(m, dtype=jnp.int32) - grp_off[expert_of_sorted]
    slot = pad_off[expert_of_sorted] + rank

    total_pad = jnp.sum(padded_counts).astype(jnp.int32)
    block_start = jnp.arange(n_blocks, dtype=jnp.int32) * token_block
    block_valid = (block_start < total_pad).astype(jnp.int32)
    # expert whose padded range contains this block's start
    cum = jnp.cumsum(padded_counts).astype(jnp.int32)
    block_expert = jnp.searchsorted(cum, block_start, side="right"
                                    ).astype(jnp.int32)
    block_expert = jnp.clip(block_expert, 0, n_experts - 1)
    return slot, block_expert, block_valid, m_pad_max


@functools.partial(jax.jit, static_argnames=("activation", "interpret",
                                             "token_block_override",
                                             "n_tokens"))
def grouped_ffn(x_sorted, params: Dict, group_sizes, activation: str = "swiglu",
                interpret: bool = True, token_block_override=None,
                n_tokens: int = 0):
    """x_sorted: (M = T*k, d) token rows grouped by expert; group_sizes: (E,).

    Returns (M, d) expert-FFN outputs in the same order.  Physical work is
    quantized to token_block rows per expert (the M_moe staircase); the
    block-size branch keys on the TOKEN count T (vLLM Table 8), passed as
    n_tokens (defaults to M when unknown).
    """
    m, d = x_sorted.shape
    e = group_sizes.shape[0]
    f = params["w_up"].shape[-1]
    token_block = token_block_override or select_token_block(
        n_tokens or m, e)
    f_tile = min(f, 512)

    expert_of_sorted = jnp.repeat(jnp.arange(e, dtype=jnp.int32), 1)[
        jnp.searchsorted(jnp.cumsum(group_sizes), jnp.arange(m), side="right")]
    slot, block_expert, block_valid, m_pad_max = align_block_size(
        expert_of_sorted, group_sizes, e, token_block)

    x_padded = jnp.zeros((m_pad_max, d), x_sorted.dtype).at[slot].set(x_sorted)
    # non-gated activations carry no gate weights — the kernel drops the
    # operand entirely rather than streaming a placeholder
    w_gate = params["w_gate"] if activation == "swiglu" else None
    out_padded = moe_ffn_pallas(
        x_padded, w_gate, params["w_up"], params["w_down"],
        block_expert, block_valid, token_block=token_block, f_tile=f_tile,
        activation=activation, interpret=interpret)
    return out_padded[slot]
