"""Pure-jnp oracle for multi-position decode attention (aligned + ragged)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, cache_len, *,
                         window: Optional[int] = None):
    """q: (b, n, h, dh); k/v_cache: (b, s, kv, dh); cache_len: scalar or (b,).

    The N query positions of row b sit at global positions
    cache_len[b] .. cache_len[b]+N-1 (their K/V already written into the
    cache).  A scalar ``cache_len`` is the aligned case; a (b,) vector is
    the scheduler's ragged per-slot case.  Returns (b, n, h, dh).
    """
    b, n, h, dh = q.shape
    s = k_cache.shape[1]
    kv = k_cache.shape[2]
    g = h // kv
    scale = 1.0 / (dh ** 0.5)
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,))
    q_pos = lens[:, None] + jnp.arange(n, dtype=jnp.int32)[None]     # (b, n)
    kv_pos = jnp.arange(s, dtype=jnp.int32)                          # (s,)
    mask = kv_pos[None, None, :] <= q_pos[:, :, None]                # (b,n,s)
    if window is not None:
        mask &= kv_pos[None, None, :] > (q_pos[:, :, None] - window)
    qg = q.reshape(b, n, kv, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache)
    scores = scores.astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return ctx.reshape(b, n, h, dh)
