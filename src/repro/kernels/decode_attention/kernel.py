"""Pallas TPU multi-position decode attention (flash-style, query-tiled).

The query-tile BlockSpec of this kernel IS the M_attn granularity of the
NFP principle: q rows are padded to ``q_block`` (selected by
``core.granularity.select_q_block``) before launch, so physical work is
quantized exactly like FlashAttention's kBlockM / FlashInfer's CTA_TILE_Q
(paper App. F) — re-derived for the TPU memory hierarchy: the q tile and
one (k_block, head_dim) KV tile live in VMEM, accumulation runs in f32
VREGs, and the scores matmul maps onto the MXU with M = g*q_block.

RAGGED PER-SLOT DECODE: ``cache_lens`` is a (b,) scalar-prefetch vector —
one committed-cache length per batch row.  This is the layout the
continuous-batching scheduler serves: every slot decodes at its own
sequence position through ONE quantized kernel launch (the FlashInfer
CTA-tile regime of paper App. F).  Each row masks its own query/kv
positions, and a per-row kv-tile upper bound ``cdiv(len_b + n, k_block)``
lets short slots SKIP kv tiles beyond their filled length: the pl.when
guard elides the tile's compute, and the K/V BlockSpec index map clamps
skipped steps to the row's last useful tile so the pipelining machinery
elides their DMA too (unchanged block index => no copy) — granularity
slack becomes observable per row (``ops.slack_report`` models exactly
this skip rule).  Aligned rows (a scalar broadcast to (b,)) reduce to the
old single-length behaviour bit-for-bit.

Layout (prepared by ops.py):
  q: (b, kv_heads, g, n_pad, dh)   g = query heads per KV head (GQA)
  k: (b, kv_heads, s_pad, dh)
  v: (b, kv_heads, s_pad, dh)
  cache_lens: (b,) i32 scalar-prefetch (positions already committed,
              per batch row; the n new positions sit at len_b .. len_b+n-1)
Output:
  o: (b, kv_heads, g, n_pad, dh)
Grid: (b, kv_heads, n_q_tiles, n_kv_tiles) — kv tiles innermost, online
softmax state in VMEM scratch persists across kv tiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(cache_lens_ref, q_ref, k_ref, v_ref, o_ref,
                 m_ref, l_ref, acc_ref, *,
                 q_block: int, k_block: int, g: int, scale: float,
                 window: Optional[int], n_kv_tiles: int, n_logical: int):
    ib = pl.program_id(0)
    iq = pl.program_id(2)
    ij = pl.program_id(3)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = cache_lens_ref[ib]

    # --- per-row kv-tile bounds (the ragged fast path) ---------------------
    # Upper: this row's cache holds cache_len + n_logical committed/new
    # positions, and within this q tile nothing past the tile's last query
    # (causal diagonal) is visible either; tiles at/after the smaller
    # boundary hold nothing the mask would keep — skipping them is free.
    row_kv_end = cache_len + jnp.minimum(n_logical, (iq + 1) * q_block)
    useful = ij * k_block < row_kv_end
    if window is not None:
        # Lower: the smallest q position in this q tile is
        # cache_len + iq*q_block; kv tiles wholly below its window are
        # invisible to every row of the tile.
        lo_visible = cache_len + iq * q_block - window + 1
        useful &= ij * k_block + k_block - 1 >= lo_visible

    @pl.when(useful)
    def _compute():
        rows = g * q_block
        q = q_ref[0, 0].reshape(rows, q_ref.shape[-1]).astype(jnp.float32)
        # dense layout blocks are (1, 1, kb, dh); paged pool blocks are
        # (1, kb, dh) — flatten either to the (kb, dh) tile
        k = k_ref[...].reshape(k_block, k_ref.shape[-1]).astype(jnp.float32)
        v = v_ref[...].reshape(k_block, v_ref.shape[-1]).astype(jnp.float32)

        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (rows, kb)

        # --- causal / window / validity mask -------------------------------
        row_ids = jax.lax.broadcasted_iota(jnp.int32, (rows, k_block), 0)
        q_off = row_ids % q_block                            # row -> q index
        q_pos = cache_len + iq * q_block + q_off
        kv_pos = (ij * k_block
                  + jax.lax.broadcasted_iota(jnp.int32, (rows, k_block), 1))
        mask = kv_pos <= q_pos
        if window is not None:
            mask &= kv_pos > (q_pos - window)
        scores = jnp.where(mask, scores, NEG_INF)

        # --- online softmax ------------------------------------------------
        m_prev = m_ref[...]
        m_cur = jnp.max(scores, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (alpha * acc_ref[...]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ij == n_kv_tiles - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        out = (acc_ref[...] / l).reshape(g, q_block, acc_ref.shape[-1])
        o_ref[0, 0] = out.astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, cache_lens, *, q_block: int,
                            k_block: int, scale: float,
                            window: Optional[int] = None,
                            n_logical: Optional[int] = None,
                            interpret: bool = False):
    """q: (b, kv, g, n_pad, dh); k/v: (b, kv, s_pad, dh); cache_lens: (b,) i32.

    ``n_logical`` is the un-padded query count (defaults to n_pad): row b's
    filled kv length is cache_lens[b] + n_logical, the per-row tile bound.
    """
    b, kv, g, n_pad, dh = q.shape
    s_pad = k.shape[2]
    n_q_tiles = n_pad // q_block
    n_kv_tiles = s_pad // k_block
    grid = (b, kv, n_q_tiles, n_kv_tiles)

    n_log = n_pad if n_logical is None else n_logical
    kernel = functools.partial(
        _attn_kernel, q_block=q_block, k_block=k_block, g=g, scale=scale,
        window=window, n_kv_tiles=n_kv_tiles, n_logical=n_log)

    def kv_index(ib, ik, iq, ij, lens_ref):
        # Clamp the kv block index to the row's useful-tile range (mirrors
        # the kernel's `useful` bounds, upper AND window lower): skipped
        # grid steps then revisit an already-resident block, and Pallas
        # elides the DMA when the block index is unchanged — so the ragged
        # skip saves HBM traffic, not just MXU work.  The fetched-but-
        # skipped content is never read (the pl.when guard), so the clamp
        # target is free to choose.
        last = jnp.maximum(
            (lens_ref[ib] + jnp.minimum(n_log, (iq + 1) * q_block)
             + k_block - 1) // k_block - 1, 0)
        idx = jnp.minimum(ij, last)
        if window is not None:
            first = jnp.maximum(
                (lens_ref[ib] + iq * q_block - window + 1) // k_block, 0)
            idx = jnp.maximum(idx, jnp.minimum(first, last))
        return (ib, ik, idx, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, q_block, dh),
                             lambda ib, ik, iq, ij, *_: (ib, ik, 0, iq, 0)),
                pl.BlockSpec((1, 1, k_block, dh), kv_index),
                pl.BlockSpec((1, 1, k_block, dh), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, g, q_block, dh),
                                   lambda ib, ik, iq, ij, *_: (ib, ik, 0, iq, 0)),
            scratch_shapes=[
                pltpu.VMEM((g * q_block, 1), jnp.float32),   # running max
                pltpu.VMEM((g * q_block, 1), jnp.float32),   # running sum
                pltpu.VMEM((g * q_block, dh), jnp.float32),  # accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, n_pad, dh), q.dtype),
        interpret=interpret,
    )(cache_lens, q, k, v)


def decode_attention_paged_pallas(q, k_pool, v_pool, cache_lens,
                                  block_tables, *, q_block: int,
                                  block_size: int, scale: float,
                                  window: Optional[int] = None,
                                  n_logical: Optional[int] = None,
                                  interpret: bool = False):
    """Block-table-indexed variant: the KV cache is a GLOBAL paged pool.

    q: (b, kv, g, n_pad, dh); k_pool/v_pool: (kv, n_phys*block_size, dh)
    — the refcounted block pool flattened along the position axis, one
    physical page per kv tile (the page size IS this launch's k_block);
    cache_lens: (b,) i32; block_tables: (b, max_blocks) i32 mapping row
    b's LOGICAL kv tile ij to a physical page.

    This is the (b,) ``cache_lens`` scalar-prefetch machinery
    generalized one step: a SECOND prefetch operand carries the block
    tables, and the K/V BlockSpec index map — the same per-row
    useful-tile clamp as the ragged dense kernel — returns
    ``bt[ib, clamp(ij)]`` instead of ``clamp(ij)``, so the DMA engine
    walks each row's (arbitrarily fragmented) page list while the
    in-kernel masks keep operating in LOGICAL positions.  The tile-skip
    rule (and therefore ``ops.slack_report``) is unchanged: a skipped
    grid step revisits the row's last useful page, and Pallas elides
    the copy when the physical page index is unchanged.  Rows whose
    table entries point at the trailing trash page (inactive slots)
    read junk that the causal mask zeroes out exactly.
    """
    b, kv, g, n_pad, dh = q.shape
    n_q_tiles = n_pad // q_block
    n_kv_tiles = block_tables.shape[1]
    grid = (b, kv, n_q_tiles, n_kv_tiles)

    n_log = n_pad if n_logical is None else n_logical
    kernel = functools.partial(
        _attn_kernel, q_block=q_block, k_block=block_size, g=g, scale=scale,
        window=window, n_kv_tiles=n_kv_tiles, n_logical=n_log)

    def paged_kernel(lens_ref, bt_ref, *refs, **kw):
        # the block tables only steer the index maps; the kernel body is
        # the ragged kernel unchanged (it masks in logical positions)
        del bt_ref
        return kernel(lens_ref, *refs, **kw)

    def kv_index(ib, ik, iq, ij, lens_ref, bt_ref):
        # identical useful-range clamp to the dense ragged kernel, then
        # mapped through the row's block table: logical tile -> physical
        # page.  Entries inside the clamp range are always valid pages
        # (allocated, or the trash page for inactive rows).
        last = jnp.maximum(
            (lens_ref[ib] + jnp.minimum(n_log, (iq + 1) * q_block)
             + block_size - 1) // block_size - 1, 0)
        idx = jnp.minimum(ij, last)
        if window is not None:
            first = jnp.maximum(
                (lens_ref[ib] + iq * q_block - window + 1) // block_size, 0)
            idx = jnp.maximum(idx, jnp.minimum(first, last))
        return (ik, bt_ref[ib, idx], 0)

    return pl.pallas_call(
        paged_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, q_block, dh),
                             lambda ib, ik, iq, ij, *_: (ib, ik, 0, iq, 0)),
                pl.BlockSpec((1, block_size, dh), kv_index),
                pl.BlockSpec((1, block_size, dh), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, g, q_block, dh),
                                   lambda ib, ik, iq, ij, *_: (ib, ik, 0, iq, 0)),
            scratch_shapes=[
                pltpu.VMEM((g * q_block, 1), jnp.float32),   # running max
                pltpu.VMEM((g * q_block, 1), jnp.float32),   # running sum
                pltpu.VMEM((g * q_block, dh), jnp.float32),  # accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, n_pad, dh), q.dtype),
        interpret=interpret,
    )(cache_lens, block_tables, q, k_pool, v_pool)
