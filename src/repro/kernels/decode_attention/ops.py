"""jit'd wrappers: layout prep + query-tile padding (the M_attn mechanism).

``decode_attention_ragged`` is the kernel entry the serving scheduler
uses: ``cache_lens`` is a (b,) vector of per-slot committed lengths, so
mixed-length slots share ONE quantized kernel launch.  The logical N
query rows are padded up to the selected q_block before launch — physical
work therefore changes only at tile boundaries (paper Eq. 33-34), which
is exactly the granularity the NFP predictor reads from
``core.granularity``.  ``decode_attention`` keeps the original aligned
(scalar ``total_len``) signature and is a broadcast of the ragged path.

``decode_attention_paged`` serves the scheduler's PAGED cache: K/V live
in a global refcounted block pool and a (b, max_blocks) block table
(second scalar-prefetch operand) maps each row's logical kv tile to a
physical page — the page size is that launch's k_block, so paging slots
straight into the same tile-skip machinery.

``slack_report`` models the kernel's physical work for one forward in
plain numpy — useful vs padded query rows, and executed vs grid kv tiles
under the kernel's per-row skip rule — so serving telemetry can place
MEASURED per-step granularity slack next to the ``core.nfp`` prediction.
The same rule covers the paged launch (pass ``k_block=block_size`` and
the block-table-covered ``s_max``): tile skipping is decided in logical
positions, independent of which physical page a tile maps to.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.granularity import cdiv, round_up, select_q_block
from repro.kernels.decode_attention.kernel import (
    decode_attention_paged_pallas, decode_attention_pallas)

K_BLOCK = 128


@functools.partial(jax.jit, static_argnames=("window", "q_block_override",
                                             "k_block", "interpret"))
def decode_attention_ragged(q, k_cache, v_cache, cache_lens, *,
                            window: Optional[int] = None,
                            q_block_override: Optional[int] = None,
                            k_block: int = K_BLOCK,
                            interpret: Optional[bool] = None):
    """q: (b, n, h, dh); k/v_cache: (b, s, kv, dh); cache_lens: (b,) i32.

    Row b's N query positions sit at cache_lens[b] .. cache_lens[b]+N-1
    (their K/V already written into the cache at those offsets).  A scalar
    ``cache_lens`` broadcasts to the aligned case.  Returns (b, n, h, dh).

    interpret=None (the default) compiles the kernel on TPU and runs the
    Pallas interpreter elsewhere (CPU validation), so engine/scheduler
    callers need no threading; pass True/False to force either.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, n, h, dh = q.shape
    s = k_cache.shape[1]
    kv = k_cache.shape[2]
    g = h // kv
    q_block = q_block_override or select_q_block(n, dh)
    n_pad = round_up(n, q_block)
    s_pad = round_up(s, k_block)
    scale = 1.0 / (dh ** 0.5)

    qk = q.reshape(b, n, kv, g, dh).transpose(0, 2, 3, 1, 4)   # (b,kv,g,n,dh)
    qk = jnp.pad(qk, ((0, 0), (0, 0), (0, 0), (0, n_pad - n), (0, 0)))
    kk = jnp.pad(k_cache.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    vk = jnp.pad(v_cache.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    lens = jnp.broadcast_to(
        jnp.asarray(cache_lens, jnp.int32).reshape(-1), (b,))

    o = decode_attention_pallas(qk, kk, vk, lens, q_block=q_block,
                                k_block=k_block, scale=scale, window=window,
                                n_logical=n, interpret=interpret)
    return o[:, :, :, :n].transpose(0, 3, 1, 2, 4).reshape(b, n, h, dh)


@functools.partial(jax.jit, static_argnames=("window", "q_block_override",
                                             "interpret"))
def decode_attention_paged(q, k_pool, v_pool, cache_lens, block_tables, *,
                           window: Optional[int] = None,
                           q_block_override: Optional[int] = None,
                           interpret: Optional[bool] = None):
    """Paged-pool kernel entry the scheduler's paged cache serves.

    q: (b, n, h, dh); k_pool/v_pool: (n_phys, bs, kv, dh) — the global
    refcounted block pool (``serving.paged``), whose page size ``bs``
    becomes this launch's kv tile (k_block); cache_lens: (b,) committed
    lengths; block_tables: (b, max_blocks) i32 logical->physical page
    map per row (unassigned entries point at the trailing trash page).

    Row b's N query positions sit at cache_lens[b] .. cache_lens[b]+N-1
    in LOGICAL positions; their K/V must already be scattered into the
    pool at the pages the table names.  Returns (b, n, h, dh).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, n, h, dh = q.shape
    n_phys, bs, kv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    g = h // kv
    q_block = q_block_override or select_q_block(n, dh)
    n_pad = round_up(n, q_block)
    scale = 1.0 / (dh ** 0.5)

    qk = q.reshape(b, n, kv, g, dh).transpose(0, 2, 3, 1, 4)   # (b,kv,g,n,dh)
    qk = jnp.pad(qk, ((0, 0), (0, 0), (0, 0), (0, n_pad - n), (0, 0)))
    # pool -> (kv, n_phys*bs, dh): one physical page per kv-tile DMA
    kk = k_pool.transpose(2, 0, 1, 3).reshape(kv, n_phys * bs, dh)
    vk = v_pool.transpose(2, 0, 1, 3).reshape(kv, n_phys * bs, dh)
    lens = jnp.broadcast_to(
        jnp.asarray(cache_lens, jnp.int32).reshape(-1), (b,))
    bt = jnp.asarray(block_tables, jnp.int32)

    o = decode_attention_paged_pallas(qk, kk, vk, lens, bt, q_block=q_block,
                                      block_size=bs, scale=scale,
                                      window=window, n_logical=n,
                                      interpret=interpret)
    return o[:, :, :, :n].transpose(0, 3, 1, 2, 4).reshape(b, n, h, dh)


@functools.partial(jax.jit, static_argnames=("window", "q_block_override",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, total_len, *,
                     window: Optional[int] = None,
                     q_block_override: Optional[int] = None,
                     interpret: Optional[bool] = None):
    """Aligned-rows entry: q: (b, n, h, dh); total_len = cache_len + n
    (scalar, every row at the same position).  See decode_attention_ragged.
    """
    n = q.shape[1]
    cache_len = jnp.asarray(total_len - n, jnp.int32).reshape(())
    return decode_attention_ragged(
        q, k_cache, v_cache, cache_len, window=window,
        q_block_override=q_block_override, interpret=interpret)


def slack_report(n: int, cache_lens, s_max: int, *,
                 head_dim: int = 128,
                 q_block: Optional[int] = None,
                 k_block: int = K_BLOCK,
                 window: Optional[int] = None,
                 active=None) -> Dict[str, float]:
    """Model one ragged decode forward's physical work (per kv head).

    Mirrors the kernel's skip rule exactly: for batch row b and q tile iq,
    kv tile ij executes iff
        ij*k_block < len_b + min(n, (iq+1)*q_block)              (upper)
        and, with a window, ij*k_block + k_block - 1 >=
            len_b + iq*q_block - window + 1                      (lower)

    Args:
      n:          logical query positions per row this forward.
      cache_lens: (b,) committed lengths (the scheduler's slot_lens).
      s_max:      allocated cache length (sets the full kv grid).
      active:     optional (b,) bool — rows carrying real requests.  Rows
                  outside it still execute (the kernel runs the whole
                  batch) but count as pure slack.

    Returns a dict:
      rows_logical / rows_physical / row_utilization   — query-row padding
      kv_tiles_useful    — executed tiles on active rows (ideal work)
      kv_tiles_executed  — tiles the ragged kernel runs (after skips)
      kv_tiles_grid      — tiles a non-ragged scalar-length kernel runs
      kv_tile_utilization = useful / executed
      kv_tiles_skipped    = grid - executed (the ragged win)
    """
    lens = np.asarray(cache_lens, np.int64).ravel()
    b = lens.size
    act = (np.ones(b, bool) if active is None
           else np.asarray(active, bool).ravel())
    qb = q_block or select_q_block(n, head_dim)
    n_pad = round_up(n, qb)
    n_q_tiles = n_pad // qb
    s_pad = round_up(s_max, k_block)
    n_kv_tiles = s_pad // k_block

    executed = 0
    useful = 0
    for bi in range(b):
        for iq in range(n_q_tiles):
            hi = lens[bi] + min(n, (iq + 1) * qb)        # kv end (exclusive)
            tiles = min(n_kv_tiles, cdiv(int(hi), k_block))
            lo_tile = 0
            if window is not None:
                # first tile whose last kv position reaches lo_visible —
                # same floor-div the kernel's kv_index clamp uses
                lo_visible = lens[bi] + iq * qb - window + 1
                lo_tile = max(0, int(lo_visible) // k_block)
            t = max(0, tiles - lo_tile)
            executed += t
            if act[bi]:
                useful += t

    rows_logical = int(act.sum()) * n
    rows_physical = b * n_pad
    grid = b * n_q_tiles * n_kv_tiles
    return {
        "n": n, "q_block": qb, "k_block": k_block,
        "rows_logical": rows_logical,
        "rows_physical": rows_physical,
        "row_utilization": rows_logical / max(rows_physical, 1),
        "kv_tiles_useful": useful,
        "kv_tiles_executed": executed,
        "kv_tiles_grid": grid,
        "kv_tile_utilization": useful / max(executed, 1),
        "kv_tiles_skipped": grid - executed,
    }
