"""jit'd wrapper: layout prep + query-tile padding (the M_attn mechanism).

``decode_attention`` pads the logical N query rows up to the selected
q_block before launching the kernel — physical work therefore changes only
at tile boundaries (paper Eq. 33-34), which is exactly the granularity the
NFP predictor reads from ``core.granularity``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.granularity import round_up, select_q_block
from repro.kernels.decode_attention.kernel import decode_attention_pallas

K_BLOCK = 128


@functools.partial(jax.jit, static_argnames=("window", "q_block_override",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, total_len, *,
                     window: Optional[int] = None,
                     q_block_override: Optional[int] = None,
                     interpret: bool = True):
    """q: (b, n, h, dh); k/v_cache: (b, s, kv, dh); total_len = cache_len + n.

    Returns (b, n, h, dh).  interpret=True validates the TPU kernel body on
    CPU; on real TPU pass interpret=False.
    """
    b, n, h, dh = q.shape
    s = k_cache.shape[1]
    kv = k_cache.shape[2]
    g = h // kv
    q_block = q_block_override or select_q_block(n, dh)
    n_pad = round_up(n, q_block)
    s_pad = round_up(s, K_BLOCK)
    scale = 1.0 / (dh ** 0.5)

    qk = q.reshape(b, n, kv, g, dh).transpose(0, 2, 3, 1, 4)   # (b,kv,g,n,dh)
    qk = jnp.pad(qk, ((0, 0), (0, 0), (0, 0), (0, n_pad - n), (0, 0)))
    kk = jnp.pad(k_cache.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    vk = jnp.pad(v_cache.transpose(0, 2, 1, 3),
                 ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    cache_len = jnp.asarray(total_len - n, jnp.int32).reshape(1)

    o = decode_attention_pallas(qk, kk, vk, cache_len, q_block=q_block,
                                k_block=K_BLOCK, scale=scale, window=window,
                                interpret=interpret)
    return o[:, :, :, :n].transpose(0, 3, 1, 2, 4).reshape(b, n, h, dh)
