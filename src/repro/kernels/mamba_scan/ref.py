"""Pure-jnp oracle for the selective scan recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, b_in, c_in, a, h0):
    """x/dt: (b, s, di); b_in/c_in: (b, s, ds); a: (di, ds); h0: (b, di, ds).

    Returns (y (b, s, di), h_final (b, di, ds)), all f32.
    """

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * a[None])
        dbx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = da * h + dbx
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b_in, 1, 0), jnp.moveaxis(c_in, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h
