"""jit'd wrapper: chunk padding (the scan-chunk granularity) + kernel call."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.granularity import round_up, select_scan_chunk
from repro.kernels.mamba_scan.kernel import mamba_scan_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_scan(x, dt, b_in, c_in, a, h0, interpret: bool = True):
    """x/dt: (b, s, di) f32; b_in/c_in: (b, s, ds) f32; a: (di, ds);
    h0: (b, di, ds).  Positions are padded to SSM_CHUNK — the scan-chunk
    granularity of the NFP principle for SSM architectures.

    Returns (y (b, s, di), h_final) — h_final is the state after the s
    REAL positions (padding uses dt=0 => identity state update).
    """
    bsz, s, di = x.shape
    chunk = select_scan_chunk(s)
    s_pad = round_up(s, chunk)
    pad = s_pad - s

    def padf(t):
        return jnp.pad(t, ((0, 0), (0, pad), (0, 0)))

    # dt=0 makes padded steps identity: h = exp(0)*h + 0
    y, h = mamba_scan_pallas(padf(x), padf(dt), padf(b_in), padf(c_in),
                             a, h0, chunk=chunk, interpret=interpret)
    return y[:, :s], h
