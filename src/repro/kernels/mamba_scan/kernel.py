"""Pallas TPU chunked selective scan (Mamba1 recurrence).

The SSM analogue of the paper's granularity mechanism: decode positions
are processed in SSM_CHUNK-position blocks (DESIGN.md §6) — physical work
is quantized to whole chunks, giving the scan-chunk term of the NFP
principle for SSM/hybrid architectures.

Recurrence (per chunk, sequential in time inside the chunk, f32 state):
    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t
    y_t = <h_t, C_t>

Layout: x/dt (b, s_pad, di); B/C (b, s_pad, ds); A (di, ds); h0 (b, di, ds).
Grid: (b, n_chunks) — chunks innermost; the running state lives in VMEM
scratch and persists across grid steps (TPU grid iterations execute
sequentially), re-initialized from h0 at chunk 0 of each batch row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, b_in_ref, c_in_ref, a_ref, h0_ref,
                 y_ref, hout_ref, state_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = h0_ref[0]

    a = a_ref[...]                                            # (di, ds)

    def step(t, h):
        x_t = x_ref[0, t, :]                                  # (di,)
        dt_t = dt_ref[0, t, :]
        b_t = b_in_ref[0, t, :]                               # (ds,)
        c_t = c_in_ref[0, t, :]
        da = jnp.exp(dt_t[:, None] * a)                       # (di, ds)
        dbx = (dt_t * x_t)[:, None] * b_t[None, :]
        h = da * h + dbx
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1)
        return h

    state_ref[...] = jax.lax.fori_loop(0, chunk, step, state_ref[...])

    @pl.when(ic == n_chunks - 1)
    def _finish():
        hout_ref[0] = state_ref[...]


def mamba_scan_pallas(x, dt, b_in, c_in, a, h0, *, chunk: int,
                      interpret: bool = False):
    """x/dt: (b, s_pad, di) f32; b_in/c_in: (b, s_pad, ds) f32;
    a: (di, ds) f32; h0: (b, di, ds) f32.  Returns (y, h_final)."""
    bsz, s_pad, di = x.shape
    ds = b_in.shape[-1]
    n_chunks = s_pad // chunk
    grid = (bsz, n_chunks)

    kernel = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, di), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, di), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, ds), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, ds), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((di, ds), lambda ib, ic: (0, 0)),
            pl.BlockSpec((1, di, ds), lambda ib, ic: (ib, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, di), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, di, ds), lambda ib, ic: (ib, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s_pad, di), jnp.float32),
            jax.ShapeDtypeStruct((bsz, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((di, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, b_in, c_in, a, h0)
