"""Mixture-of-Experts FFN: top-k routing + grouped GEMM (ragged_dot).

Dispatch pipeline (paper Sec. 3.3: dispatch -> expert FFN -> weighted
combine):
  1. router logits -> top-k expert ids + renormalized weights,
  2. token-expert pairs sorted by expert id (contiguous expert groups),
  3. grouped GEMM over expert groups via ``jax.lax.ragged_dot`` —
     the XLA-native analogue of the fused MoE kernels the paper inspects.
     The Pallas path (``repro.kernels.moe_ffn``) additionally pads each
     group to the ``token_block`` granularity — the M_moe mechanism.
  4. weighted scatter-add combine (eta = 2 accesses, Eq. 17).

Controlled routing (paper App. C.3.1) is supported via
``routing_override`` so benchmarks can reproduce the load-balanced
(round-robin, Eq. 25) and load-skewed patterns exactly.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.arch import FFNSpec
from repro.models.layers import _init

Array = jax.Array


def init_moe(key, d_model: int, f: FFNSpec, dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, 5)
    e, dff = f.n_experts, f.d_ff
    p = {
        "router": _init(ks[0], (d_model, e), scale=0.02, dtype=jnp.float32),
        "w_up": _init(ks[1], (e, d_model, dff), dtype=dtype),
        "w_down": _init(ks[2], (e, dff, d_model), dtype=dtype),
    }
    if f.activation == "swiglu":
        p["w_gate"] = _init(ks[3], (e, d_model, dff), dtype=dtype)
    if f.n_shared_experts:
        p["shared_up"] = _init(ks[4], (d_model, f.n_shared_experts * dff),
                               dtype=dtype)
        p["shared_down"] = _init(
            jax.random.fold_in(ks[4], 1), (f.n_shared_experts * dff, d_model),
            dtype=dtype)
    return p


def route_topk(router_w: Array, x: Array, k: int) -> Tuple[Array, Array, Array]:
    """Returns (weights (T,k) f32, idx (T,k) i32, router_probs (T,E) f32)."""
    logits = (x.astype(jnp.float32) @ router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(top_vals, axis=-1)     # renormalize over top-k
    return weights, top_idx, probs


def balanced_routing(n_tokens: int, k: int, n_experts: int) -> Array:
    """Paper Eq. 25: round-robin {(i*k + j) mod E} — the load-balanced
    (upper-bound) controlled pattern."""
    i = jnp.arange(n_tokens, dtype=jnp.int32)[:, None]
    j = jnp.arange(k, dtype=jnp.int32)[None, :]
    return (i * k + j) % n_experts


def skewed_routing(n_tokens: int, k: int, n_experts: int) -> Array:
    """All tokens on the same k experts — the load-skewed (lower-bound)
    pattern."""
    del n_experts
    j = jnp.arange(k, dtype=jnp.int32)[None, :]
    return jnp.broadcast_to(j, (n_tokens, k))


def moe_ffn(params, f: FFNSpec, x: Array,
            routing_override: Optional[Tuple[Array, Array]] = None,
            use_kernel: bool = False,
            ) -> Tuple[Array, Array]:
    """x: (..., d) -> (out (..., d), aux_loss scalar).

    routing_override: (idx (T,k), weights (T,k)) for controlled patterns.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    e, k = f.n_experts, f.top_k

    if routing_override is not None:
        top_idx, weights = routing_override
        weights = weights.astype(jnp.float32)
        aux = jnp.zeros((), jnp.float32)
    else:
        weights, top_idx, probs = route_topk(params["router"], xt, k)
        # switch-style load-balance aux loss
        frac = jnp.mean(jax.nn.one_hot(top_idx, e, dtype=jnp.float32),
                        axis=(0, 1))
        imp = jnp.mean(probs, axis=0)
        aux = e * jnp.sum(frac * imp)

    # --- dispatch: sort token-expert pairs by expert ----------------------
    flat_idx = top_idx.reshape(-1)                    # (T*k,)
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_idx)                     # stable
    token_of_pair = order // k
    x_sorted = xt[token_of_pair]                      # (T*k, d)
    group_sizes = jnp.bincount(flat_idx, length=e).astype(jnp.int32)

    # --- expert FFN: grouped GEMM -----------------------------------------
    if use_kernel:
        from repro.kernels.moe_ffn.ops import grouped_ffn
        h_out = grouped_ffn(x_sorted, params, group_sizes, f.activation,
                            n_tokens=t)
    else:
        up = jax.lax.ragged_dot(x_sorted, params["w_up"], group_sizes)
        if f.activation == "swiglu":
            gate = jax.lax.ragged_dot(x_sorted, params["w_gate"], group_sizes)
            h = (jax.nn.silu(gate.astype(jnp.float32))
                 * up.astype(jnp.float32)).astype(x.dtype)
        else:
            h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
        h_out = jax.lax.ragged_dot(h, params["w_down"], group_sizes)

    # --- combine: weighted scatter-add (eta = 2 accesses, Eq. 17) ---------
    contrib = h_out.astype(jnp.float32) * flat_w[order][:, None]
    out = jnp.zeros((t, d), jnp.float32).at[token_of_pair].add(contrib)
    out = out.astype(x.dtype)

    if f.n_shared_experts:
        sh = jax.nn.gelu((xt @ params["shared_up"]).astype(jnp.float32))
        out = out + (sh.astype(x.dtype) @ params["shared_down"])

    return out.reshape(orig_shape), aux
