"""repro.models — composable model zoo (all 10 assigned architectures)."""
from repro.models.attention import (attention_decode, attention_full,
                                    init_attention, init_kv_cache)
from repro.models.layers import (embed, init_embedding, init_mlp,
                                 init_rmsnorm, lm_head, mlp, rmsnorm,
                                 softmax_cross_entropy)
from repro.models.mamba import (init_mamba1, init_mamba1_state, init_mamba2,
                                init_mamba2_state, mamba1_block, mamba2_block)
from repro.models.moe import (balanced_routing, init_moe, moe_ffn,
                              skewed_routing)
from repro.models.transformer import (encode, forward, init_cache,
                                      init_model, make_segments)

__all__ = [n for n in dir() if not n.startswith("_")]
