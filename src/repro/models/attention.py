"""Attention variants: GQA (covers MHA/MQA), sliding-window GQA, and MLA.

Three execution modes:
  - "full":   self-attention over the whole sequence (train / prefill).
  - "decode": multi-position decode forward — the paper's Eq. 2: N new
              positions attend to a pre-filled KV cache + each other.
  - "cross":  encoder-decoder cross attention (whisper).

The decode path can route the attention core through the Pallas
query-tiled kernel (``repro.kernels.decode_attention``) whose q-block IS
the M_attn granularity of the NFP principle; the default XLA path is the
semantically identical reference.  The kernel serves BOTH cache layouts:
a scalar ``cache_len`` (single-request drivers, aligned rows) and a (b,)
vector (the scheduler's slotted cache) go through the same ragged
entry — per-row lengths ride the kernel's scalar-prefetch lane, so
mixed-length slots share one quantized launch.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.arch import AttentionSpec
from repro.models.layers import _init, apply_rope, init_rmsnorm, rmsnorm

Array = jax.Array


# ===========================================================================
# Parameter init
# ===========================================================================

def init_attention(key, d_model: int, a: AttentionSpec, dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, 8)
    if a.kind == "mla":
        qk_h = a.qk_nope_head_dim + a.qk_rope_head_dim
        return {
            "wq_a": _init(ks[0], (d_model, a.q_lora_rank), dtype=dtype),
            "q_norm": init_rmsnorm(a.q_lora_rank, dtype),
            "wq_b": _init(ks[1], (a.q_lora_rank, a.n_heads * qk_h), dtype=dtype),
            "wkv_a": _init(ks[2], (d_model, a.kv_lora_rank + a.qk_rope_head_dim),
                           dtype=dtype),
            "kv_norm": init_rmsnorm(a.kv_lora_rank, dtype),
            "wkv_b": _init(ks[3], (a.kv_lora_rank,
                                   a.n_heads * (a.qk_nope_head_dim + a.v_head_dim)),
                           dtype=dtype),
            "wo": _init(ks[4], (a.n_heads * a.v_head_dim, d_model), dtype=dtype),
        }
    return {
        "wq": _init(ks[0], (d_model, a.n_heads * a.head_dim), dtype=dtype),
        "wk": _init(ks[1], (d_model, a.n_kv_heads * a.head_dim), dtype=dtype),
        "wv": _init(ks[2], (d_model, a.n_kv_heads * a.head_dim), dtype=dtype),
        "wo": _init(ks[3], (a.n_heads * a.head_dim, d_model), dtype=dtype),
    }


def init_kv_cache(batch: int, max_len: int, a: AttentionSpec,
                  dtype=jnp.bfloat16) -> Dict:
    """Pre-allocated decode cache (paper App. C.1.3 discipline)."""
    if a.kind == "mla":
        return {
            "latent": jnp.zeros((batch, max_len, a.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, a.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, max_len, a.n_kv_heads, a.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, a.n_kv_heads, a.head_dim), dtype),
    }


def init_paged_kv_cache(n_phys: int, block_size: int, a: AttentionSpec,
                        dtype=jnp.bfloat16) -> Dict:
    """Paged decode cache: a GLOBAL pool of ``n_phys`` blocks of
    ``block_size`` positions, shared by all slots through per-slot block
    tables (``serving.paged.BlockManager``).  The last block is the
    write-dump page unattached table entries point at."""
    if a.kind == "mla":
        return {
            "latent": jnp.zeros((n_phys, block_size, a.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((n_phys, block_size, a.qk_rope_head_dim),
                                dtype),
        }
    return {
        "k": jnp.zeros((n_phys, block_size, a.n_kv_heads, a.head_dim), dtype),
        "v": jnp.zeros((n_phys, block_size, a.n_kv_heads, a.head_dim), dtype),
    }


# ===========================================================================
# Attention cores
# ===========================================================================

def _gqa_core(q: Array, k: Array, v: Array, mask: Array, scale: float) -> Array:
    """q: (b,sq,h,dh)  k/v: (b,sk,kv,dh)  mask: (b,sq,sk) bool -> (b,sq,h,dh).

    Grouped without materializing repeated KV heads.
    """
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return ctx.reshape(b, sq, h, dh)


def _row_offsets(cache_len, batch: int) -> Array:
    """Per-row cache lengths: a scalar ``cache_len`` (every row at the
    same position — the single-request drivers) or a (b,) vector (the
    scheduler's slotted cache, each slot at its own length)."""
    cl = jnp.asarray(cache_len, jnp.int32)
    if cl.ndim == 0:
        return jnp.full((batch,), cl, jnp.int32)
    return cl


def _update_rows(cache: Array, new: Array, offsets: Array) -> Array:
    """Write ``new`` (b, n, ...) into ``cache`` (b, s, ...) at per-row
    sequence offsets (vmapped dynamic_update_slice)."""
    def one(c, x, off):
        start = (off,) + (0,) * (c.ndim - 1)
        return jax.lax.dynamic_update_slice(c, x, start)
    return jax.vmap(one)(cache, new, offsets)


def _paged_write_idx(block_tables: Array, q_pos: Array, block_size: int,
                     n_phys: int) -> Array:
    """Flat pool slots (page*block_size + offset) for per-row positions
    (b, n).  Positions past the table's coverage — e.g. junk rows of a
    width-bucketed batched forward on an inactive slot — fall through to
    the trailing trash page, never a live block."""
    b, max_blocks = block_tables.shape
    blk_idx = jnp.clip(q_pos // block_size, 0, max_blocks - 1)
    page = jnp.take_along_axis(block_tables, blk_idx, axis=1)
    page = jnp.where(q_pos < max_blocks * block_size, page, n_phys - 1)
    return page * block_size + q_pos % block_size


def _paged_update(pool: Array, new: Array, flat_idx: Array) -> Array:
    """Scatter ``new`` (b, n, ...) into the pool (n_phys, bs, ...) at
    flat slot indices (b, n).  Live-block destinations are disjoint by
    construction (writes require refcount-1 ownership; see
    ``serving.paged``); only trash-page slots may collide, where the
    winner is irrelevant."""
    n_phys, bs = pool.shape[0], pool.shape[1]
    flat = pool.reshape((n_phys * bs,) + pool.shape[2:])
    flat = flat.at[flat_idx.reshape(-1)].set(
        new.reshape((-1,) + new.shape[2:]))
    return flat.reshape(pool.shape)


def _paged_gather(pool: Array, block_tables: Array) -> Array:
    """Materialize each row's VIRTUAL contiguous cache from the pool:
    (n_phys, bs, ...) + (b, max_blocks) -> (b, max_blocks*bs, ...).
    The XLA reference path for paged decode — the Pallas path never
    materializes this, its DMA index map walks the table instead."""
    n_phys, bs = pool.shape[0], pool.shape[1]
    b, max_blocks = block_tables.shape
    flat = pool.reshape((n_phys * bs,) + pool.shape[2:])
    idx = (block_tables[:, :, None] * bs
           + jnp.arange(bs, dtype=jnp.int32)[None, None, :])
    return flat[idx.reshape(b, max_blocks * bs)]


def _causal_mask(q_pos: Array, kv_pos: Array,
                 window: Optional[int] = None,
                 kv_valid: Optional[Array] = None) -> Array:
    """q_pos: (b,sq) kv_pos: (b,sk) -> (b,sq,sk) bool."""
    m = kv_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        m &= kv_pos[:, None, :] > (q_pos[:, :, None] - window)
    if kv_valid is not None:
        m &= kv_valid[:, None, :]
    return m


# ===========================================================================
# GQA / SWA
# ===========================================================================

def gqa_full(params, a: AttentionSpec, x: Array, positions: Array,
             theta: float, build_cache: Optional[Dict] = None,
             cache_len: int = 0, causal: bool = True,
             ) -> Tuple[Array, Optional[Dict]]:
    """Self-attention over x (train / prefill).  Optionally fills a cache."""
    b, s, d = x.shape
    q = (x @ params["wq"]).reshape(b, s, a.n_heads, a.head_dim)
    k = (x @ params["wk"]).reshape(b, s, a.n_kv_heads, a.head_dim)
    v = (x @ params["wv"]).reshape(b, s, a.n_kv_heads, a.head_dim)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    window = a.window if a.kind == "swa" else None
    if causal:
        mask = _causal_mask(positions, positions, window)
    else:
        mask = jnp.ones((b, s, s), bool)
    scale = 1.0 / (a.head_dim ** 0.5)
    ctx = _gqa_core(q, k, v, mask, scale)
    out = ctx.reshape(b, s, -1) @ params["wo"]
    new_cache = None
    if build_cache is not None:
        new_cache = {
            "k": jax.lax.dynamic_update_slice(
                build_cache["k"], k, (0, cache_len, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                build_cache["v"], v, (0, cache_len, 0, 0)),
        }
    return out, new_cache


def gqa_decode(params, a: AttentionSpec, x: Array, cache: Dict,
               cache_len, theta: float,
               use_kernel: bool = False) -> Tuple[Array, Dict]:
    """Multi-position decode forward: N new positions vs cache (Eq. 2).

    ``cache_len`` may be a scalar (all rows aligned) or a (b,) vector
    (scheduler-slotted cache: each batch row decodes at its own length).
    """
    b, n, d = x.shape
    s_max = cache["k"].shape[1]
    per_row = jnp.ndim(cache_len) > 0
    offsets = _row_offsets(cache_len, b)
    q_pos = offsets[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]  # (b,n)
    q = (x @ params["wq"]).reshape(b, n, a.n_heads, a.head_dim)
    k = (x @ params["wk"]).reshape(b, n, a.n_kv_heads, a.head_dim)
    v = (x @ params["wv"]).reshape(b, n, a.n_kv_heads, a.head_dim)
    q = apply_rope(q, q_pos, theta)
    k = apply_rope(k, q_pos, theta)
    if per_row:
        k_cache = _update_rows(cache["k"], k, offsets)
        v_cache = _update_rows(cache["v"], v, offsets)
    else:
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k,
                                               (0, cache_len, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v,
                                               (0, cache_len, 0, 0))
    kv_pos = jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32)[None, :],
                              (b, s_max))
    window = a.window if a.kind == "swa" else None
    scale = 1.0 / (a.head_dim ** 0.5)
    if use_kernel:
        # Ragged per-slot fast path: the (b,) offsets vector goes straight
        # into the kernel's scalar-prefetch lane, so scheduler-slotted
        # batches (each row at its own length) share one quantized launch;
        # the scalar case is the same kernel with aligned rows.
        from repro.kernels.decode_attention.ops import decode_attention_ragged
        ctx = decode_attention_ragged(q, k_cache, v_cache, offsets,
                                      window=window)
    else:
        mask = _causal_mask(q_pos, kv_pos, window)
        ctx = _gqa_core(q, k_cache, v_cache, mask, scale)
    out = ctx.reshape(b, n, -1) @ params["wo"]
    return out, {"k": k_cache, "v": v_cache}


def gqa_decode_paged(params, a: AttentionSpec, x: Array, cache: Dict,
                     cache_len, block_tables: Array, theta: float,
                     use_kernel: bool = False) -> Tuple[Array, Dict]:
    """Paged multi-position decode: the cache is a global block pool
    (``init_paged_kv_cache``) indexed through per-row block tables.

    Identical math to ``gqa_decode`` — the N new positions' K/V are
    scattered to the pages the table names, then attention runs over
    each row's virtual cache (gathered for the XLA path; walked by the
    block-table DMA index map on the Pallas path).  Junk rows of a
    batched forward write to the trash page, so a live block is only
    ever written by the slot that owns it.
    """
    b, n, d = x.shape
    bs = cache["k"].shape[1]
    n_phys = cache["k"].shape[0]
    offsets = _row_offsets(cache_len, b)
    q_pos = offsets[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]
    q = (x @ params["wq"]).reshape(b, n, a.n_heads, a.head_dim)
    k = (x @ params["wk"]).reshape(b, n, a.n_kv_heads, a.head_dim)
    v = (x @ params["wv"]).reshape(b, n, a.n_kv_heads, a.head_dim)
    q = apply_rope(q, q_pos, theta)
    k = apply_rope(k, q_pos, theta)
    bt = jnp.asarray(block_tables, jnp.int32)
    flat_idx = _paged_write_idx(bt, q_pos, bs, n_phys)
    k_pool = _paged_update(cache["k"], k, flat_idx)
    v_pool = _paged_update(cache["v"], v, flat_idx)
    window = a.window if a.kind == "swa" else None
    scale = 1.0 / (a.head_dim ** 0.5)
    if use_kernel:
        from repro.kernels.decode_attention.ops import decode_attention_paged
        ctx = decode_attention_paged(q, k_pool, v_pool, offsets, bt,
                                     window=window)
    else:
        k_virt = _paged_gather(k_pool, bt)
        v_virt = _paged_gather(v_pool, bt)
        s_virt = k_virt.shape[1]
        kv_pos = jnp.broadcast_to(
            jnp.arange(s_virt, dtype=jnp.int32)[None, :], (b, s_virt))
        mask = _causal_mask(q_pos, kv_pos, window)
        ctx = _gqa_core(q, k_virt, v_virt, mask, scale)
    out = ctx.reshape(b, n, -1) @ params["wo"]
    return out, {"k": k_pool, "v": v_pool}


def gqa_decode_ring(params, a: AttentionSpec, x: Array, cache: Dict,
                    cache_len, theta: float) -> Tuple[Array, Dict]:
    """Sliding-window decode over a RING buffer of size W_buf >= window+N.

    Global position p lives in slot p % W_buf; the slot's current content
    is the LARGEST written position congruent to the slot index, which is
    computable from (slot, total_written) without storing positions:
        p_s = s + W_buf * ((L_tot - 1 - s) // W_buf)   if L_tot > 0.
    Memory: O(window) instead of O(sequence) — 128x smaller for
    mixtral long_500k (window 4096 vs 524k cache).
    """
    b, n, d = x.shape
    w_buf = cache["k"].shape[1]
    q_pos = cache_len + jnp.arange(n, dtype=jnp.int32)[None, :]
    q_pos = jnp.broadcast_to(q_pos, (b, n))
    q = (x @ params["wq"]).reshape(b, n, a.n_heads, a.head_dim)
    k = (x @ params["wk"]).reshape(b, n, a.n_kv_heads, a.head_dim)
    v = (x @ params["wv"]).reshape(b, n, a.n_kv_heads, a.head_dim)
    q = apply_rope(q, q_pos, theta)
    k = apply_rope(k, q_pos, theta)
    slots = (cache_len + jnp.arange(n, dtype=jnp.int32)) % w_buf
    k_cache = cache["k"].at[:, slots].set(k)
    v_cache = cache["v"].at[:, slots].set(v)
    # position currently stored in each slot (after the writes above)
    l_tot = cache_len + n
    s_idx = jnp.arange(w_buf, dtype=jnp.int32)
    p_s = s_idx + w_buf * ((l_tot - 1 - s_idx) // w_buf)
    p_s = jnp.where(l_tot > 0, p_s, -1)
    kv_pos = jnp.broadcast_to(p_s[None, :], (b, w_buf))
    window = a.window or w_buf
    mask = _causal_mask(q_pos, kv_pos, window,
                        kv_valid=kv_pos >= 0)
    scale = 1.0 / (a.head_dim ** 0.5)
    ctx = _gqa_core(q, k_cache, v_cache, mask, scale)
    out = ctx.reshape(b, n, -1) @ params["wo"]
    return out, {"k": k_cache, "v": v_cache}


def cross_attention(params, a: AttentionSpec, x: Array,
                    enc_k: Array, enc_v: Array) -> Array:
    """Whisper decoder cross-attn: kv precomputed from encoder memory."""
    b, n, d = x.shape
    q = (x @ params["wq"]).reshape(b, n, a.n_heads, a.head_dim)
    mask = jnp.ones((b, n, enc_k.shape[1]), bool)
    scale = 1.0 / (a.head_dim ** 0.5)
    ctx = _gqa_core(q, enc_k, enc_v, mask, scale)
    return ctx.reshape(b, n, -1) @ params["wo"]


def encode_cross_kv(params, a: AttentionSpec, memory: Array) -> Tuple[Array, Array]:
    b, s, d = memory.shape
    k = (memory @ params["wk"]).reshape(b, s, a.n_kv_heads, a.head_dim)
    v = (memory @ params["wv"]).reshape(b, s, a.n_kv_heads, a.head_dim)
    return k, v


# ===========================================================================
# MLA (MiniCPM3 / DeepSeek-style multi-head latent attention)
# ===========================================================================

def _mla_q(params, a: AttentionSpec, x: Array, q_pos: Array, theta: float):
    b, n, _ = x.shape
    qk_h = a.qk_nope_head_dim + a.qk_rope_head_dim
    q = rmsnorm(params["q_norm"], x @ params["wq_a"]) @ params["wq_b"]
    q = q.reshape(b, n, a.n_heads, qk_h)
    q_nope = q[..., : a.qk_nope_head_dim]
    q_rope = apply_rope(q[..., a.qk_nope_head_dim:], q_pos, theta)
    return q_nope, q_rope


def _mla_latent(params, a: AttentionSpec, x: Array, pos: Array, theta: float):
    kv = x @ params["wkv_a"]
    latent = rmsnorm(params["kv_norm"], kv[..., : a.kv_lora_rank])
    k_rope = kv[..., a.kv_lora_rank:]
    # shared-rope key: rotate as a single "head"
    k_rope = apply_rope(k_rope[..., None, :], pos, theta)[..., 0, :]
    return latent, k_rope


def mla_full(params, a: AttentionSpec, x: Array, positions: Array,
             theta: float, build_cache: Optional[Dict] = None,
             cache_len: int = 0) -> Tuple[Array, Optional[Dict]]:
    """Non-absorbed MLA for train/prefill: decompress K/V and run GQA-style."""
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(params, a, x, positions, theta)
    latent, k_rope = _mla_latent(params, a, x, positions, theta)
    wkv_b = params["wkv_b"].reshape(a.kv_lora_rank, a.n_heads,
                                    a.qk_nope_head_dim + a.v_head_dim)
    kv = jnp.einsum("bsl,lhd->bshd", latent, wkv_b)
    k_nope = kv[..., : a.qk_nope_head_dim]
    v = kv[..., a.qk_nope_head_dim:]
    scale = 1.0 / ((a.qk_nope_head_dim + a.qk_rope_head_dim) ** 0.5)
    mask = _causal_mask(positions, positions)
    scores = (jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope))
    scores = scores.astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    out = ctx.reshape(b, s, -1) @ params["wo"]
    new_cache = None
    if build_cache is not None:
        new_cache = {
            "latent": jax.lax.dynamic_update_slice(
                build_cache["latent"], latent, (0, cache_len, 0)),
            "k_rope": jax.lax.dynamic_update_slice(
                build_cache["k_rope"], k_rope, (0, cache_len, 0)),
        }
    return out, new_cache


def mla_decode(params, a: AttentionSpec, x: Array, cache: Dict,
               cache_len, theta: float) -> Tuple[Array, Dict]:
    """Absorbed MLA decode: scores computed directly against the latent
    cache (KV traffic = latent bytes — the d_latent term in the NFP model)."""
    b, n, _ = x.shape
    s_max = cache["latent"].shape[1]
    per_row = jnp.ndim(cache_len) > 0
    offsets = _row_offsets(cache_len, b)
    q_pos = offsets[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]
    q_nope, q_rope = _mla_q(params, a, x, q_pos, theta)
    latent_new, k_rope_new = _mla_latent(params, a, x, q_pos, theta)
    if per_row:
        latent = _update_rows(cache["latent"], latent_new, offsets)
        k_rope = _update_rows(cache["k_rope"], k_rope_new, offsets)
    else:
        latent = jax.lax.dynamic_update_slice(cache["latent"], latent_new,
                                              (0, cache_len, 0))
        k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new,
                                              (0, cache_len, 0))
    wkv_b = params["wkv_b"].reshape(a.kv_lora_rank, a.n_heads,
                                    a.qk_nope_head_dim + a.v_head_dim)
    wk = wkv_b[..., : a.qk_nope_head_dim]           # (lora, h, d_nope)
    wv = wkv_b[..., a.qk_nope_head_dim:]            # (lora, h, d_v)
    # absorb the key decompression into the query
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, wk)
    scores = (jnp.einsum("bqhl,bsl->bhqs", q_lat, latent)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope))
    scale = 1.0 / ((a.qk_nope_head_dim + a.qk_rope_head_dim) ** 0.5)
    kv_pos = jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32)[None, :],
                              (b, s_max))
    mask = _causal_mask(q_pos, kv_pos)
    scores = jnp.where(mask[:, None, :, :], scores.astype(jnp.float32) * scale,
                       -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhqs,bsl->bqhl", probs, latent)
    ctx = jnp.einsum("bqhl,lhd->bqhd", ctx_lat, wv)
    out = ctx.reshape(b, n, -1) @ params["wo"]
    return out, {"latent": latent, "k_rope": k_rope}


def mla_decode_paged(params, a: AttentionSpec, x: Array, cache: Dict,
                     cache_len, block_tables: Array, theta: float
                     ) -> Tuple[Array, Dict]:
    """Absorbed MLA decode over a paged latent pool (XLA path only —
    the Pallas kernel serves GQA/SWA geometries, as in the dense case)."""
    b, n, _ = x.shape
    bs = cache["latent"].shape[1]
    n_phys = cache["latent"].shape[0]
    offsets = _row_offsets(cache_len, b)
    q_pos = offsets[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :]
    q_nope, q_rope = _mla_q(params, a, x, q_pos, theta)
    latent_new, k_rope_new = _mla_latent(params, a, x, q_pos, theta)
    bt = jnp.asarray(block_tables, jnp.int32)
    flat_idx = _paged_write_idx(bt, q_pos, bs, n_phys)
    latent_pool = _paged_update(cache["latent"], latent_new, flat_idx)
    k_rope_pool = _paged_update(cache["k_rope"], k_rope_new, flat_idx)
    latent = _paged_gather(latent_pool, bt)
    k_rope = _paged_gather(k_rope_pool, bt)
    s_virt = latent.shape[1]
    wkv_b = params["wkv_b"].reshape(a.kv_lora_rank, a.n_heads,
                                    a.qk_nope_head_dim + a.v_head_dim)
    wk = wkv_b[..., : a.qk_nope_head_dim]
    wv = wkv_b[..., a.qk_nope_head_dim:]
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, wk)
    scores = (jnp.einsum("bqhl,bsl->bhqs", q_lat, latent)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope))
    scale = 1.0 / ((a.qk_nope_head_dim + a.qk_rope_head_dim) ** 0.5)
    kv_pos = jnp.broadcast_to(jnp.arange(s_virt, dtype=jnp.int32)[None, :],
                              (b, s_virt))
    mask = _causal_mask(q_pos, kv_pos)
    scores = jnp.where(mask[:, None, :, :], scores.astype(jnp.float32) * scale,
                       -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhqs,bsl->bqhl", probs, latent)
    ctx = jnp.einsum("bqhl,lhd->bqhd", ctx_lat, wv)
    out = ctx.reshape(b, n, -1) @ params["wo"]
    return out, {"latent": latent_pool, "k_rope": k_rope_pool}


# ===========================================================================
# Dispatch
# ===========================================================================

def attention_full(params, a: AttentionSpec, x, positions, theta,
                   build_cache=None, cache_len: int = 0, causal: bool = True):
    if a.kind == "mla":
        return mla_full(params, a, x, positions, theta, build_cache, cache_len)
    return gqa_full(params, a, x, positions, theta, build_cache, cache_len,
                    causal)


def attention_decode(params, a: AttentionSpec, x, cache, cache_len, theta,
                     use_kernel: bool = False, swa_ring: bool = False,
                     block_tables=None):
    if block_tables is not None:
        if a.kind == "mla":
            return mla_decode_paged(params, a, x, cache, cache_len,
                                    block_tables, theta)
        return gqa_decode_paged(params, a, x, cache, cache_len, block_tables,
                                theta, use_kernel)
    if a.kind == "mla":
        return mla_decode(params, a, x, cache, cache_len, theta)
    if swa_ring and a.kind == "swa":
        return gqa_decode_ring(params, a, x, cache, cache_len, theta)
    return gqa_decode(params, a, x, cache, cache_len, theta, use_kernel)
