"""Shared model layers: norms, RoPE, MLPs, embeddings.

Pure-JAX parameter pytrees (dicts) + apply functions.  bf16 weights,
f32 normalization/softmax internals (matches the s=2 traffic assumption
of the NFP model).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _init(key, shape, scale=None, dtype=jnp.bfloat16):
    if scale is None:
        scale = 1.0 / (shape[0] ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., s, hd/2)
    cos = jnp.cos(ang)[..., None, :]                                # (..., s, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, activation: str = "swiglu",
             dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    p = {"up": _init(ks[0], (d_model, d_ff), dtype=dtype),
         "down": _init(ks[1], (d_ff, d_model), dtype=dtype)}
    if activation == "swiglu":
        p["gate"] = _init(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def mlp(params, x: Array, activation: str = "swiglu") -> Array:
    up = x @ params["up"]
    if activation == "swiglu":
        gate = jax.nn.silu((x @ params["gate"]).astype(jnp.float32))
        h = (gate * up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return h @ params["down"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.bfloat16):
    return {"table": _init(key, (vocab, d_model), scale=0.02, dtype=dtype)}


def embed(params, tokens: Array) -> Array:
    return params["table"][tokens]


def init_lm_head(key, d_model: int, vocab: int, dtype=jnp.bfloat16):
    return {"w": _init(key, (d_model, vocab), dtype=dtype)}


def lm_head(params, x: Array) -> Array:
    return x @ params["w"]


def unembed_tied(embed_params, x: Array) -> Array:
    return x @ embed_params["table"].T


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: Array, labels: Array,
                          mask: Optional[Array] = None) -> Array:
    """Mean next-token CE; logits (b, s, v), labels (b, s) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
