"""Composable decoder / encoder-decoder assembly for all 10 assigned archs.

Layers are grouped into *segments* of identical kind (attn / ssm / hybrid)
and each segment is executed with ``jax.lax.scan`` over stacked per-layer
parameters — the HLO contains one layer body per segment regardless of
depth, which keeps multi-pod dry-run compiles tractable and lets XLA
overlap per-layer collectives with the next iteration's compute.

Modes:
  train   — full causal self-attention, no cache, returns logits (+aux).
  prefill — same math, fills the pre-allocated decode cache.
  decode  — the paper's multi-position decode forward (Eq. 2): N new
            positions against a cache of length ``cache_len``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.arch import (LAYER_ATTN, LAYER_HYBRID, LAYER_SSM, ArchConfig)
from repro.models.attention import (attention_decode, attention_full,
                                    cross_attention, encode_cross_kv,
                                    init_attention, init_kv_cache,
                                    init_paged_kv_cache)
from repro.models.layers import (embed, init_embedding, init_lm_head,
                                 init_mlp, init_rmsnorm, lm_head, mlp,
                                 rmsnorm, unembed_tied)
from repro.models.mamba import (init_mamba1, init_mamba1_state, init_mamba2,
                                init_mamba2_state, mamba1_block, mamba2_block)
from repro.models.moe import init_moe, moe_ffn

Array = jax.Array


# ===========================================================================
# Segments
# ===========================================================================

def make_segments(cfg: ArchConfig) -> List[Tuple[str, int]]:
    """Group the layer pattern into runs of identical kind."""
    segs: List[Tuple[str, int]] = []
    for kind in cfg.pattern():
        if segs and segs[-1][0] == kind:
            segs[-1] = (kind, segs[-1][1] + 1)
        else:
            segs.append((kind, 1))
    return segs


def _tree_stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ===========================================================================
# Init
# ===========================================================================

def _init_layer(key, cfg: ArchConfig, kind: str, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Dict = {"ln1": init_rmsnorm(d, dtype)}
    if kind == LAYER_ATTN:
        p["attn"] = init_attention(ks[0], d, cfg.attention, dtype)
        p["ln2"] = init_rmsnorm(d, dtype)
        if cfg.ffn.kind == "moe":
            p["ffn"] = init_moe(ks[1], d, cfg.ffn, dtype)
        elif cfg.ffn.kind == "dense":
            p["ffn"] = init_mlp(ks[1], d, cfg.ffn.d_ff, cfg.ffn.activation,
                                dtype)
        if cfg.encoder is not None:  # whisper decoder layer: cross-attn
            p["ln_cross"] = init_rmsnorm(d, dtype)
            p["cross"] = init_attention(ks[2], d, cfg.attention, dtype)
    elif kind == LAYER_SSM:
        init_fn = init_mamba1 if cfg.ssm.kind == "mamba1" else init_mamba2
        p["ssm"] = init_fn(ks[0], d, cfg.ssm, dtype)
    elif kind == LAYER_HYBRID:
        init_fn = init_mamba1 if cfg.ssm.kind == "mamba1" else init_mamba2
        p["ssm"] = init_fn(ks[0], d, cfg.ssm, dtype)
        p["ln_shared"] = init_rmsnorm(d, dtype)
    return p


def init_model(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Dict:
    keys = jax.random.split(key, cfg.n_layers + 8)
    params: Dict = {
        "embed": init_embedding(keys[-1], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_lm_head(keys[-2], cfg.d_model,
                                         cfg.vocab_size, dtype)
    segs, li = [], 0
    for kind, count in make_segments(cfg):
        layers = [_init_layer(keys[li + i], cfg, kind, dtype)
                  for i in range(count)]
        li += count
        segs.append(_tree_stack(layers))
    params["segments"] = segs
    if cfg.shared_attention:
        params["shared_attn"] = {
            "attn": init_attention(keys[-3], cfg.d_model, cfg.attention,
                                   dtype),
            "ln2": init_rmsnorm(cfg.d_model, dtype),
            "ffn": init_mlp(keys[-4], cfg.d_model,
                            cfg.ffn.d_ff or 4 * cfg.d_model,
                            cfg.ffn.activation, dtype),
        }
    if cfg.encoder is not None:
        enc_layers = []
        for i in range(cfg.encoder.n_layers):
            k = jax.random.fold_in(keys[-5], i)
            ks = jax.random.split(k, 2)
            enc_layers.append({
                "ln1": init_rmsnorm(cfg.d_model, dtype),
                "attn": init_attention(ks[0], cfg.d_model, cfg.attention,
                                       dtype),
                "ln2": init_rmsnorm(cfg.d_model, dtype),
                "ffn": init_mlp(ks[1], cfg.d_model, cfg.ffn.d_ff,
                                cfg.ffn.activation, dtype),
            })
        params["encoder"] = {
            "layers": _tree_stack(enc_layers),
            "final_norm": init_rmsnorm(cfg.d_model, dtype),
        }
    return params


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, swa_ring: bool = False,
               ring_headroom: int = 128) -> Dict:
    """Pre-allocated decode state for every segment (App. C.1.3 discipline).

    swa_ring: sliding-window archs allocate an O(window) RING buffer
    (window + ring_headroom decode positions, 16-aligned) instead of
    O(max_len) — pair with forward(..., swa_ring=True)."""
    attn_len = max_len
    if (swa_ring and cfg.attention is not None
            and cfg.attention.kind == "swa" and cfg.attention.window):
        ring = ((cfg.attention.window + ring_headroom + 15) // 16) * 16
        attn_len = min(max_len, ring)
    segs = []
    for kind, count in make_segments(cfg):
        if kind == LAYER_ATTN:
            c = [init_kv_cache(batch, attn_len, cfg.attention, dtype)
                 for _ in range(count)]
            segs.append(_tree_stack(c))
        elif kind == LAYER_SSM:
            fn = (init_mamba1_state if cfg.ssm.kind == "mamba1"
                  else init_mamba2_state)
            segs.append(_tree_stack([fn(batch, cfg.d_model, cfg.ssm)
                                     for _ in range(count)]))
        else:  # hybrid: ssm state + shared-attn kv cache
            fn = (init_mamba1_state if cfg.ssm.kind == "mamba1"
                  else init_mamba2_state)
            c = [{"ssm_state": fn(batch, cfg.d_model, cfg.ssm),
                  "attn": init_kv_cache(batch, max_len, cfg.attention, dtype)}
                 for _ in range(count)]
            segs.append(_tree_stack(c))
    return {"segments": segs}


def init_paged_cache(cfg: ArchConfig, n_phys: int, block_size: int,
                     dtype=jnp.bfloat16) -> Dict:
    """Paged decode state: every attention layer shares ONE logical
    block layout (the per-slot block tables in ``serving.paged``), each
    layer owning its own (n_phys, block_size, ...) pool.  Paging covers
    KV caches only — recurrent (SSM/hybrid) state and encoder memory
    have no sequence axis to page, so those archs keep the dense cache
    (``DecodeEngine`` rejects them in paged mode)."""
    segs = []
    for kind, count in make_segments(cfg):
        if kind != LAYER_ATTN:
            raise ValueError("paged KV cache supports attention-only "
                             f"architectures; {cfg.name} has a {kind} segment")
        c = [init_paged_kv_cache(n_phys, block_size, cfg.attention, dtype)
             for _ in range(count)]
        segs.append(_tree_stack(c))
    return {"segments": segs}


# ===========================================================================
# Layer bodies
# ===========================================================================

def _ffn_apply(lp, cfg: ArchConfig, h: Array, routing_override):
    if cfg.ffn.kind == "moe":
        out, aux = moe_ffn(lp["ffn"], cfg.ffn, h,
                           routing_override=routing_override)
        return out, aux
    if cfg.ffn.kind == "dense":
        return mlp(lp["ffn"], h, cfg.ffn.activation), jnp.zeros((), jnp.float32)
    return jnp.zeros_like(h), jnp.zeros((), jnp.float32)


def _attn_layer(lp, cfg: ArchConfig, x: Array, positions, cache, cache_len,
                mode: str, use_kernel: bool, routing_override,
                memory: Optional[Array], swa_ring: bool = False,
                block_tables=None):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if mode == "decode":
        att, new_cache = attention_decode(lp["attn"], cfg.attention, h, cache,
                                          cache_len, cfg.rope_theta,
                                          use_kernel, swa_ring,
                                          block_tables=block_tables)
    else:
        att, new_cache = attention_full(lp["attn"], cfg.attention, h,
                                        positions, cfg.rope_theta,
                                        build_cache=cache, cache_len=0)
    x = x + att
    if memory is not None and "cross" in lp:
        hc = rmsnorm(lp["ln_cross"], x, cfg.norm_eps)
        ck, cv = encode_cross_kv(lp["cross"], cfg.attention, memory)
        x = x + cross_attention(lp["cross"], cfg.attention, hc, ck, cv)
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    ff, aux = _ffn_apply(lp, cfg, h2, routing_override)
    return x + ff, new_cache, aux


def _ssm_layer(lp, cfg: ArchConfig, x: Array, state, use_kernel: bool):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    block = mamba1_block if cfg.ssm.kind == "mamba1" else mamba2_block
    if cfg.ssm.kind == "mamba1":
        out, new_state = block(lp["ssm"], cfg.ssm, h, state, use_kernel)
    else:
        out, new_state = block(lp["ssm"], cfg.ssm, h, state)
    return x + out, new_state


def _hybrid_layer(lp, shared, cfg: ArchConfig, x: Array, positions, cache,
                  cache_len, mode: str, use_kernel: bool):
    ssm_state = None if cache is None else cache["ssm_state"]
    x, new_ssm = _ssm_layer(lp, cfg, x, ssm_state, use_kernel)
    # shared attention block (zamba2-style: one param set reused)
    h = rmsnorm(lp["ln_shared"], x, cfg.norm_eps)
    attn_cache = None if cache is None else cache["attn"]
    if mode == "decode":
        att, new_attn = attention_decode(shared["attn"], cfg.attention, h,
                                         attn_cache, cache_len,
                                         cfg.rope_theta, use_kernel)
    else:
        att, new_attn = attention_full(shared["attn"], cfg.attention, h,
                                       positions, cfg.rope_theta,
                                       build_cache=attn_cache, cache_len=0)
    x = x + att
    h2 = rmsnorm(shared["ln2"], x, cfg.norm_eps)
    x = x + mlp(shared["ffn"], h2, cfg.ffn.activation)
    if cache is None:
        return x, None
    return x, {"ssm_state": new_ssm, "attn": new_attn}


# ===========================================================================
# Forward
# ===========================================================================

def _sinusoidal(positions: Array, d: int) -> Array:
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params, cfg: ArchConfig, frames: Array) -> Array:
    """Whisper-style encoder over stub frame embeddings (b, F, d)."""
    b, f, d = frames.shape
    pos = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f))
    x = (frames.astype(jnp.float32) + _sinusoidal(pos, d)).astype(frames.dtype)
    ep = params["encoder"]

    def body(carry, lp):
        x = carry
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        att, _ = attention_full(lp["attn"], cfg.attention, h, pos,
                                cfg.rope_theta, causal=False)
        x = x + att
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        return x + mlp(lp["ffn"], h2, cfg.ffn.activation), 0.0

    x, _ = jax.lax.scan(body, x, ep["layers"])
    return rmsnorm(ep["final_norm"], x, cfg.norm_eps)


def forward(params, cfg: ArchConfig, inputs: Dict, *, mode: str = "train",
            cache: Optional[Dict] = None, cache_len=0,
            use_kernel: bool = False, routing_override=None,
            remat=False, swa_ring: bool = False, block_tables=None,
            ) -> Tuple[Array, Optional[Dict], Array, Array]:
    """Returns (logits, new_cache, moe_aux_loss, hidden).

    ``block_tables`` (b, max_blocks) i32 switches decode-mode attention
    onto the PAGED cache path: ``cache`` must then be an
    ``init_paged_cache`` pool and ``cache_len`` a (b,) per-slot length
    vector (``serving.paged`` owns the table bookkeeping).

    ``hidden`` is the final-norm output (b, s, d) — the representation
    the LM head (and any auxiliary head bank, e.g. MTP) reads.  Serving
    threads it out so multi-token-prediction proposals consume the real
    last hidden state rather than an embedding-row proxy.

    inputs: {"tokens": (b,s) i32} or {"embeds": (b,s,d)}; whisper adds
    {"frames": (b,F,d)} (stub frontend output).

    remat: False / True / float fraction in (0,1) — fractional remat
    checkpoints only the first ceil(frac*L) layers of each segment and
    saves the rest's activations (perf iteration #3: cuts the recompute
    flops multiplier from 4x toward 3x where memory allows).
    """
    if "embeds" in inputs:
        x = inputs["embeds"]
    else:
        x = embed(params["embed"], inputs["tokens"])
    b, s = x.shape[0], x.shape[1]

    memory = None
    if cfg.encoder is not None:
        memory = encode(params, cfg, inputs["frames"])
        pos0 = jnp.asarray(cache_len if mode == "decode" else 0, jnp.int32)
        if pos0.ndim == 1:                       # per-slot cache lengths
            pos0 = pos0[:, None]
        tok_pos = pos0 + jnp.arange(s, dtype=jnp.int32)[None]
        x = (x.astype(jnp.float32)
             + _sinusoidal(jnp.broadcast_to(tok_pos, (b, s)), cfg.d_model)
             ).astype(x.dtype)

    if mode == "decode":
        positions = None
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))

    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)
    new_segments = []
    segments = make_segments(cfg)
    for si, (kind, count) in enumerate(segments):
        sp = params["segments"][si]
        seg_cache = None if cache is None else cache["segments"][si]

        if kind == LAYER_ATTN:
            def body(x, inp, _kind=kind):
                lp, lc = inp
                y, nc, aux = _attn_layer(lp, cfg, x, positions, lc, cache_len,
                                         mode, use_kernel, routing_override,
                                         memory, swa_ring, block_tables)
                return y, (nc, aux)
        elif kind == LAYER_SSM:
            def body(x, inp, _kind=kind):
                lp, lc = inp
                y, ns = _ssm_layer(lp, cfg, x, lc, use_kernel)
                return y, (ns, jnp.zeros((), jnp.float32))
        else:
            def body(x, inp, _kind=kind):
                lp, lc = inp
                y, nc = _hybrid_layer(lp, shared, cfg, x, positions, lc,
                                      cache_len, mode, use_kernel)
                return y, (nc, jnp.zeros((), jnp.float32))

        frac = (1.0 if remat is True else
                0.0 if remat is False else float(remat))

        if cache is None:
            # scan without cache: feed layer params only
            def body_nc(x, lp, _body=body):
                y, (nc, aux) = _body(x, (lp, None))
                return y, aux
            n_re = int(round(frac * count))
            aux_parts = []
            if n_re > 0:
                sp_re = (jax.tree.map(lambda a: a[:n_re], sp)
                         if n_re < count else sp)
                x, a1 = jax.lax.scan(jax.checkpoint(body_nc), x, sp_re)
                aux_parts.append(a1)
            if n_re < count:
                sp_pl = (jax.tree.map(lambda a: a[n_re:], sp)
                         if n_re > 0 else sp)
                x, a2 = jax.lax.scan(body_nc, x, sp_pl)
                aux_parts.append(a2)
            auxs = jnp.concatenate([jnp.atleast_1d(a) for a in aux_parts])
            new_segments.append(None)
        else:
            if frac > 0:
                body = jax.checkpoint(body)
            x, (ncs, auxs) = jax.lax.scan(body, x, (sp, seg_cache))
            new_segments.append(ncs)
        aux_total = aux_total + jnp.sum(auxs)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed_tied(params["embed"], x)
    else:
        logits = lm_head(params["lm_head"], x)
    new_cache = None if cache is None else {"segments": new_segments}
    return logits, new_cache, aux_total, x
