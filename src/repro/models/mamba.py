"""Mamba1 (falcon-mamba) and Mamba2 (zamba2) state-space blocks.

Both support:
  - "full" mode: scan over the whole sequence (train / prefill),
  - "decode" mode: N new positions advancing a cached (conv, ssm) state —
    the SSM analogue of the multi-position decode forward.  The Pallas
    chunked-scan kernel (``repro.kernels.mamba_scan``) processes positions
    in SSM_CHUNK blocks — the scan-chunk granularity term of DESIGN.md §6.

Projections are stored UNPACKED (in_x / in_z / in_B / ...) rather than as
one fused in_proj: each matrix then has a clean tensor-parallel
PartitionSpec (d_inner sharded over the model axis) with no mid-tensor
splits — the per-channel recurrence and depthwise conv stay fully local
under TP, and only out_proj reduces over the sharded dim (one psum),
megatron-style.  State dtype is f32; activations bf16.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.arch import SSMSpec
from repro.models.layers import _init, rmsnorm

Array = jax.Array


# ===========================================================================
# Depthwise causal conv1d
# ===========================================================================

def causal_conv1d(x: Array, w: Array, b: Array,
                  conv_state: Optional[Array] = None,
                  ) -> Tuple[Array, Array]:
    """x: (batch, s, c); w: (d_conv, c); returns (out (batch,s,c), new_state).

    conv_state: (batch, d_conv-1, c) trailing inputs from previous steps.
    Depthwise == per-channel, so channel sharding keeps it collective-free.
    """
    d_conv = w.shape[0]
    batch, s, c = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((batch, d_conv - 1, c), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = jnp.zeros((batch, s, c), jnp.float32)
    for j in range(d_conv):
        out = out + xp[:, j:j + s].astype(jnp.float32) * w[j].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_state = xp[:, -(d_conv - 1):] if d_conv > 1 else conv_state
    return jax.nn.silu(out).astype(x.dtype), new_state


# ===========================================================================
# Mamba1
# ===========================================================================

def init_mamba1(key, d_model: int, s: SSMSpec, dtype=jnp.bfloat16) -> Dict:
    di = s.d_inner(d_model)
    dt_rank = max(1, d_model // 16)
    ks = jax.random.split(key, 8)
    return {
        "in_x": _init(ks[0], (d_model, di), dtype=dtype),
        "in_z": _init(ks[1], (d_model, di), dtype=dtype),
        "conv_w": _init(ks[2], (s.d_conv, di), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _init(ks[3], (di, dt_rank + 2 * s.d_state), dtype=dtype),
        "dt_proj": _init(ks[4], (dt_rank, di), dtype=dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
            (di, s.d_state)) + 0.0),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[5], (di, d_model), dtype=dtype),
    }


def init_mamba1_state(batch: int, d_model: int, s: SSMSpec) -> Dict:
    di = s.d_inner(d_model)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di), jnp.bfloat16),
        "ssm": jnp.zeros((batch, di, s.d_state), jnp.float32),
    }


def _mamba1_scan(xs: Array, dts: Array, bs_: Array, cs: Array, a: Array,
                 h0: Array) -> Tuple[Array, Array]:
    """xs,dts: (b,s,di); bs_,cs: (b,s,ds); a: (di,ds); h0: (b,di,ds)."""

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * a[None])                # (b,di,ds)
        dbx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = da * h + dbx
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs_t = jnp.moveaxis(xs, 1, 0)
    dts_t = jnp.moveaxis(dts, 1, 0)
    bs_t = jnp.moveaxis(bs_, 1, 0)
    cs_t = jnp.moveaxis(cs, 1, 0)
    h, ys = jax.lax.scan(step, h0, (xs_t, dts_t, bs_t, cs_t))
    return jnp.moveaxis(ys, 0, 1), h


def mamba1_block(params, s: SSMSpec, x: Array,
                 state: Optional[Dict] = None,
                 use_kernel: bool = False) -> Tuple[Array, Optional[Dict]]:
    """x: (batch, seq, d_model) -> (out, new_state)."""
    batch, seq, d_model = x.shape
    di = s.d_inner(d_model)
    dt_rank = max(1, d_model // 16)
    x_in = x @ params["in_x"]
    z = x @ params["in_z"]
    conv_state = state["conv"] if state is not None else None
    x_conv, new_conv = causal_conv1d(x_in, params["conv_w"], params["conv_b"],
                                     conv_state)
    proj = x_conv @ params["x_proj"]
    dt = proj[..., :dt_rank] @ params["dt_proj"]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    b_ssm = proj[..., dt_rank:dt_rank + s.d_state].astype(jnp.float32)
    c_ssm = proj[..., dt_rank + s.d_state:].astype(jnp.float32)
    a = -jnp.exp(params["A_log"])
    h0 = (state["ssm"] if state is not None
          else jnp.zeros((batch, di, s.d_state), jnp.float32))
    if use_kernel:
        from repro.kernels.mamba_scan.ops import selective_scan
        ys, h = selective_scan(x_conv.astype(jnp.float32), dt, b_ssm, c_ssm,
                               a, h0)
    else:
        ys, h = _mamba1_scan(x_conv.astype(jnp.float32), dt, b_ssm, c_ssm,
                             a, h0)
    y = ys + params["D"] * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    new_state = {"conv": new_conv, "ssm": h} if state is not None else None
    return out, new_state


# ===========================================================================
# Mamba2 (scalar per-head decay; SSD recurrence form)
# ===========================================================================

def init_mamba2(key, d_model: int, s: SSMSpec, dtype=jnp.bfloat16) -> Dict:
    di = s.d_inner(d_model)
    nh = di // s.head_dim
    ng = s.n_groups
    ks = jax.random.split(key, 10)
    return {
        "in_x": _init(ks[0], (d_model, di), dtype=dtype),
        "in_z": _init(ks[1], (d_model, di), dtype=dtype),
        "in_B": _init(ks[2], (d_model, ng * s.d_state), dtype=dtype),
        "in_C": _init(ks[3], (d_model, ng * s.d_state), dtype=dtype),
        "in_dt": _init(ks[4], (d_model, nh), dtype=dtype),
        "conv_w": _init(ks[5], (s.d_conv, di), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "convB_w": _init(ks[6], (s.d_conv, ng * s.d_state), scale=0.5,
                         dtype=dtype),
        "convB_b": jnp.zeros((ng * s.d_state,), dtype),
        "convC_w": _init(ks[7], (s.d_conv, ng * s.d_state), scale=0.5,
                         dtype=dtype),
        "convC_b": jnp.zeros((ng * s.d_state,), dtype),
        "A_logh": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), dtype)},
        "out_proj": _init(ks[8], (di, d_model), dtype=dtype),
    }


def init_mamba2_state(batch: int, d_model: int, s: SSMSpec) -> Dict:
    di = s.d_inner(d_model)
    nh = di // s.head_dim
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di), jnp.bfloat16),
        "convB": jnp.zeros((batch, s.d_conv - 1, s.n_groups * s.d_state),
                           jnp.bfloat16),
        "convC": jnp.zeros((batch, s.d_conv - 1, s.n_groups * s.d_state),
                           jnp.bfloat16),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def mamba2_block(params, s: SSMSpec, x: Array,
                 state: Optional[Dict] = None) -> Tuple[Array, Optional[Dict]]:
    batch, seq, d_model = x.shape
    di = s.d_inner(d_model)
    nh = di // s.head_dim
    ng = s.n_groups
    ds = s.d_state
    z = x @ params["in_z"]
    x_in = x @ params["in_x"]
    b_raw = x @ params["in_B"]
    c_raw = x @ params["in_C"]
    dt_raw = x @ params["in_dt"]
    cs = state if state is not None else {}
    x_conv, new_conv = causal_conv1d(x_in, params["conv_w"], params["conv_b"],
                                     cs.get("conv"))
    b_conv, new_convB = causal_conv1d(b_raw, params["convB_w"],
                                      params["convB_b"], cs.get("convB"))
    c_conv, new_convC = causal_conv1d(c_raw, params["convC_w"],
                                      params["convC_b"], cs.get("convC"))
    x_f = x_conv.astype(jnp.float32)
    b_ssm = b_conv.reshape(batch, seq, ng, ds).astype(jnp.float32)
    c_ssm = c_conv.reshape(batch, seq, ng, ds).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_logh"])                                 # (nh,)
    xh = x_f.reshape(batch, seq, nh, s.head_dim)
    rep = nh // ng

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp      # (b,nh,dh) (b,nh) (b,ng,ds) (b,ng,ds)
        da = jnp.exp(dt_t * a)          # (b,nh)
        b_h = jnp.repeat(b_t, rep, axis=1)   # (b,nh,ds)
        c_h = jnp.repeat(c_t, rep, axis=1)
        upd = (dt_t[..., None] * x_t)[..., None] * b_h[:, :, None, :]
        h = da[..., None, None] * h + upd
        y = jnp.einsum("bhds,bhs->bhd", h, c_h)
        return h, y

    h0 = (state["ssm"] if state is not None
          else jnp.zeros((batch, nh, s.head_dim, ds), jnp.float32))
    xs_t = jnp.moveaxis(xh, 1, 0)
    dt_t = jnp.moveaxis(dt, 1, 0)
    b_t = jnp.moveaxis(b_ssm, 1, 0)
    c_t = jnp.moveaxis(c_ssm, 1, 0)
    h, ys = jax.lax.scan(step, h0, (xs_t, dt_t, b_t, c_t))
    y = jnp.moveaxis(ys, 0, 1)                                # (b,s,nh,dh)
    y = y + params["D"][:, None] * xh
    y = y.reshape(batch, seq, di)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = rmsnorm(params["norm"], y)
    out = y @ params["out_proj"]
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "convB": new_convB,
                     "convC": new_convC, "ssm": h}
    return out, new_state
