"""pjit-able train step: CE loss + MoE aux, microbatch gradient
accumulation (lax.scan), per-layer remat, optional gradient compression.

The microbatch scan serves two production purposes at once: it bounds
live activation memory (global_batch/n_micro per step) and it gives XLA a
sequential structure whose per-microbatch gradient reductions overlap
with the next microbatch's compute.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.arch import ArchConfig
from repro.models.layers import softmax_cross_entropy
from repro.models.transformer import forward
from repro.training.optimizer import AdamWConfig, adamw_update


def loss_fn(params, cfg: ArchConfig, batch: Dict, aux_weight: float = 0.01,
            remat: bool = True):
    fwd_in = {}
    if "embeds" in batch:            # vlm: stub frontend provides embeddings
        fwd_in["embeds"] = batch["embeds"]
    else:
        fwd_in["tokens"] = batch["tokens"]
    if "frames" in batch:            # audio: stub frontend frame embeddings
        fwd_in["frames"] = batch["frames"]
    logits, _, aux, _ = forward(params, cfg, fwd_in, mode="train",
                                remat=remat)
    ce = softmax_cross_entropy(logits[:, :-1], batch["tokens"][:, 1:],
                               batch.get("mask"))
    return ce + aux_weight * aux, {"ce": ce, "moe_aux": aux}


def compress_grads(grads, enabled: bool):
    """bf16 gradient compression: halves all-reduce bytes on the wire.
    With error compensation left to the f32 accumulator (the bf16
    round-trip happens before accumulation)."""
    if not enabled:
        return grads
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)


def grad_accum_fn(params, cfg: ArchConfig, batch: Dict, n_micro: int,
                  aux_weight: float = 0.01, remat: bool = True,
                  compress: bool = False):
    """Gradient over the global batch via a scan of n_micro microbatches.

    batch["tokens"] may be pre-shaped (n_micro, mb, s) — preferred at
    scale, so the microbatch split arrives already sharded and no
    resharding all-to-all is inserted at step start.
    """
    if batch["tokens"].ndim == 3:
        micro = batch
        if batch["tokens"].shape[0] != n_micro:
            raise ValueError(
                f"pre-split batch has {batch['tokens'].shape[0]} "
                f"microbatches, expected n_micro={n_micro}")
    else:
        b = batch["tokens"].shape[0]
        if b % n_micro:
            raise ValueError(
                f"batch size {b} is not divisible by n_micro={n_micro}")
        mb = b // n_micro
        micro = jax.tree.map(
            lambda x: x.reshape(n_micro, mb, *x.shape[1:]), batch)

    def one(carry, mbatch):
        gacc, lacc = carry
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, mbatch, aux_weight, remat)
        grads = compress_grads(grads, compress)
        gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / n_micro,
                            gacc, grads)
        return (gacc, lacc + loss / n_micro), metrics["ce"]

    gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss), ces = jax.lax.scan(one, (gz, jnp.zeros(())), micro)
    return grads, loss, jnp.mean(ces)


def train_step(params, opt_state, batch: Dict, *, cfg: ArchConfig,
               opt_cfg: AdamWConfig, n_micro: int = 1,
               aux_weight: float = 0.01, remat: bool = True,
               compress: bool = False):
    """One optimizer step.  Pure function of (params, opt_state, batch) —
    pjit this with the sharding rules from repro.dist."""
    if n_micro > 1:
        grads, loss, ce = grad_accum_fn(params, cfg, batch, n_micro,
                                        aux_weight, remat, compress)
    else:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, aux_weight, remat)
        grads = compress_grads(jax.tree.map(lambda g: g.astype(jnp.float32),
                                            grads), compress)
        ce = metrics["ce"]
    new_params, new_opt, om = adamw_update(opt_cfg, params, grads, opt_state)
    metrics = {"loss": loss, "ce": ce, **om}
    return new_params, new_opt, metrics


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, n_micro: int = 1,
                    remat: bool = True, compress: bool = False):
    return functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                             n_micro=n_micro, remat=remat, compress=compress)
