"""Hand-rolled AdamW with f32 master weights over bf16 params,
global-norm clipping, and warmup+cosine LR schedules.

Optimizer state pytree:
  {"master": f32 params, "m": f32, "v": f32, "step": i32 scalar}
bf16 params are re-derived from the master copy each update (mixed
precision: bf16 compute/weights, f32 optimizer math).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_ratio*lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio * cfg.lr + (1 - cfg.min_lr_ratio) * cfg.lr * 0.5 \
        * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict:
    f32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(jnp.zeros_like, f32)
    return {"master": f32, "m": zeros,
            "v": jax.tree.map(jnp.zeros_like, f32),
        "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _decay_mask(path_leaf) -> bool:
    """No weight decay on norms / biases / scalar SSM params."""
    name = "/".join(str(p) for p in path_leaf)
    return not any(k in name for k in ("scale", "bias", "A_log", "A_logh", "D",
                                       "dt_bias"))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state
                 ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params (original dtypes), new_opt_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    m = jax.tree.map(lambda mo, g: b1 * mo + (1 - b1) * g,
                     opt_state["m"], grads)
    v = jax.tree.map(lambda vo, g: b2 * vo + (1 - b2) * g * g,
                     opt_state["v"], grads)

    paths = jax.tree_util.tree_flatten_with_path(opt_state["master"])[0]
    decay_flags = [(1.0 if _decay_mask(p) else 0.0) for p, _ in paths]
    flat_master, treedef = jax.tree_util.tree_flatten(opt_state["master"])
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    new_master = []
    for p, mo, vo, wd in zip(flat_master, flat_m, flat_v, decay_flags):
        update = (mo / bc1) / (jnp.sqrt(vo / bc2) + cfg.eps)
        update = update + cfg.weight_decay * wd * p
        new_master.append(p - lr * update)
    master = jax.tree_util.tree_unflatten(treedef, new_master)
    # params keep their original dtypes (bf16 weights, f32 A_log/router/...)
    new_params = jax.tree.map(lambda mast, old: mast.astype(old.dtype),
                              master, params)
    new_state = {"master": master, "m": m, "v": v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
