"""repro.training — optimizer, train step, schedules."""
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      clip_by_global_norm, global_norm,
                                      init_opt_state, lr_schedule)
from repro.training.train_step import (grad_accum_fn, loss_fn,
                                       make_train_step, train_step)

__all__ = [n for n in dir() if not n.startswith("_")]
