"""Elastic / fault-tolerant training primitives.

Three pieces the launchers compose:

  - ``elastic_mesh``:     pick a mesh factorization for however many
                          devices the (possibly degraded) fleet has,
  - ``StepWatchdog``:     flag persistent stragglers from step latencies,
  - ``run_with_restarts``: drive a step function with
                          restore-from-checkpoint recovery on failure.

None of this imports jax device state at module level — the dry run must
be able to set XLA_FLAGS first.
"""
from __future__ import annotations

from typing import Callable, Tuple

POD_CHIPS = 256          # one pod = 16 x 16 chips
POD_SHAPE = (16, 16)
MAX_MODEL_AXIS = 16


def elastic_mesh(n_devices: int) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Mesh factorization for an elastic fleet of ``n_devices`` chips.

    Full multiples of a pod keep the production (pod, data, model) /
    (data, model) layouts; a degraded fleet (node failures removed some
    hosts) falls back to the largest model axis <= 16 that divides the
    device count, with everything else on the data axis.
    """
    if n_devices <= 0:
        raise ValueError(f"n_devices must be positive, got {n_devices}")
    if n_devices > POD_CHIPS and n_devices % POD_CHIPS == 0:
        return ((n_devices // POD_CHIPS, *POD_SHAPE),
                ("pod", "data", "model"))
    if n_devices == POD_CHIPS:
        return (POD_SHAPE, ("data", "model"))
    model = max(d for d in range(1, min(MAX_MODEL_AXIS, n_devices) + 1)
                if n_devices % d == 0)
    return ((n_devices // model, model), ("data", "model"))


class StepWatchdog:
    """Flags a persistent straggler: ``observe(dt)`` returns True once
    ``max_misses`` consecutive steps exceeded the deadline.

    A single slow step (compile, checkpoint flush, transient network
    stall) is normal; consecutive misses mean a degraded host that the
    launcher should restart away from.
    """

    def __init__(self, deadline_s: float, max_misses: int = 2):
        self.deadline_s = float(deadline_s)
        self.max_misses = int(max_misses)
        self.misses = 0
        self.observed = 0

    def observe(self, step_seconds: float) -> bool:
        self.observed += 1
        if step_seconds > self.deadline_s:
            self.misses += 1
        else:
            self.misses = 0
        return self.misses >= self.max_misses


def run_with_restarts(step_fn: Callable[[int], None], start: int,
                      total: int, restore_fn: Callable[[], int], *,
                      retry_transient: bool = True,
                      max_restarts: int = 8) -> int:
    """Run ``step_fn(step)`` for ``step in [start, total)`` with
    restore-and-resume recovery.

    On an exception the step is optionally retried once in place
    (``retry_transient`` — covers flaky I/O without paying a rollback);
    if it fails again, ``restore_fn()`` rolls state back to the last
    checkpoint and returns the step to resume from.  More than
    ``max_restarts`` rollbacks re-raises: the failure is deterministic
    and restarting cannot help.
    """
    step = start
    restarts = 0
    while step < total:
        try:
            step_fn(step)
        except Exception:
            if retry_transient:
                try:
                    step_fn(step)
                    step += 1
                    continue
                except Exception:
                    pass
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore_fn()
            continue
        step += 1
    return total
