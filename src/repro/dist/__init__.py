"""repro.dist — sharded execution: expert parallelism, sharding rules,
elastic / fault-tolerant training."""
from repro.dist.elastic import (StepWatchdog, elastic_mesh,
                                run_with_restarts)
from repro.dist.ep_moe import ep_moe_ffn
from repro.dist.sharding import (batch_pspec, cache_pspecs, mesh_axes,
                                 opt_pspecs, param_pspecs,
                                 shardings_from_pspecs)

__all__ = [
    "StepWatchdog", "elastic_mesh", "run_with_restarts", "ep_moe_ffn",
    "batch_pspec", "cache_pspecs", "mesh_axes", "opt_pspecs",
    "param_pspecs", "shardings_from_pspecs",
]
