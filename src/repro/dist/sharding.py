"""Sharding rules: PartitionSpecs for params / optimizer / batch / cache.

One rule table serves the trainer, the dry-run compiler and the serving
stack.  Everything is divisibility-checked against the actual leaf
shapes and the actual mesh, falling back to replication — a rule that
does not divide evenly is silently weaker, never an XLA error.

Policies (``param_pspecs``):
  fsdp     2D: tensor-parallel over the ``model`` axis by role, plus a
           ZeRO-3-style shard of a remaining dim over the data axes.
  auto     alias of fsdp (the measured default; see EXPERIMENTS notes in
           launch/specs.py for the MoE/TP regression that motivated it).
  tp_only  tensor-parallel only; weights replicated across data axes.
  dp_only  fully replicated params (pure data parallelism).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[str, Tuple[str, ...]]


# ===========================================================================
# Mesh introspection
# ===========================================================================

def mesh_axes(mesh: Mesh) -> Tuple[Axes, str]:
    """(fsdp_axes, model_axis): the data-parallel axes (a single name or a
    tuple — e.g. ("pod", "data") on the multi-pod mesh) and the
    tensor/expert-parallel axis."""
    names = tuple(mesh.axis_names)
    model = "model" if "model" in names else names[-1]
    dp = tuple(a for a in names if a != model)
    if len(dp) == 1:
        return dp[0], model
    return dp, model


def _dp_tuple(mesh: Mesh) -> Tuple[str, ...]:
    dp, _ = mesh_axes(mesh)
    return dp if isinstance(dp, tuple) else (dp,)


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(math.prod(mesh.shape[a] for a in axes))


# ===========================================================================
# Batch
# ===========================================================================

def batch_pspec(mesh: Mesh, batch: int, include_model: bool = False) -> P:
    """Pspec for a (batch, seq) input: batch sharded over as many
    data axes as divide it (plus the model axis for dp_only training,
    where the whole fleet is one big data-parallel group)."""
    cand = list(_dp_tuple(mesh))
    if include_model:
        cand.append(mesh_axes(mesh)[1])
    used = []
    size = 1
    for a in cand:
        if batch % (size * mesh.shape[a]) == 0:
            used.append(a)
            size *= mesh.shape[a]
    if not used:
        return P(None, None)
    return P(tuple(used) if len(used) > 1 else used[0], None)


# ===========================================================================
# Params
# ===========================================================================

# role -> which dim (negative, so stacked-layer leading dims are
# transparent) is tensor-parallel.  Output-projection weights shard the
# contracting (input) dim so the row-parallel matmul finishes with one
# psum, matching the Megatron column/row pairing.
_TP_LAST = ("wq", "wk", "wv", "w_up", "w_gate", "wq_b", "wkv_b",
            "shared_up", "lm_head", "in_proj", "up", "gate")
_TP_PENULT = ("wo", "w_down", "shared_down", "out_proj", "down")
_TP_DIM0 = ("table",)                        # embedding: shard the vocab dim
_REPLICATED = ("scale", "bias", "router", "A_log", "A_logh", "D", "dt_bias",
               "q_norm", "kv_norm", "conv")


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path).lower()


def _tp_dim(name: str, ndim: int) -> Optional[int]:
    last = name.rsplit("'", 2)
    leaf = last[-2] if len(last) >= 2 else name
    if any(r in leaf for r in _REPLICATED):
        return None
    if any(leaf.endswith(r) or r in leaf for r in _TP_PENULT):
        return ndim - 2 if ndim >= 2 else None
    if any(leaf.endswith(r) or r in leaf for r in _TP_LAST):
        return ndim - 1
    if "table" in leaf and ndim >= 2:
        return ndim - 2                       # (V, d) / (L, V, d): vocab dim
    return None


def param_pspecs(params, mesh: Mesh, policy: str = "fsdp"):
    """Tree of PartitionSpecs matching ``params``."""
    if policy not in ("fsdp", "auto", "tp_only", "dp_only"):
        raise ValueError(f"unknown sharding policy {policy!r}")
    dp = _dp_tuple(mesh)
    dp_size = _axes_size(mesh, dp)
    _, model = mesh_axes(mesh)
    model_size = mesh.shape[model]

    def leaf_spec(path, leaf):
        shape = leaf.shape
        ndim = len(shape)
        if ndim == 0 or policy == "dp_only":
            return P()
        dims: list = [None] * ndim
        name = _leaf_name(path)
        td = _tp_dim(name, ndim)
        if td is not None and shape[td] % model_size == 0 and model_size > 1:
            dims[td] = model
        if policy in ("fsdp", "auto") and dp_size > 1:
            # ZeRO-style: shard the largest still-free dim over data axes
            free = [i for i in range(ndim)
                    if dims[i] is None and shape[i] % dp_size == 0]
            if free:
                big = max(free, key=lambda i: shape[i])
                if shape[big] >= dp_size:
                    dims[big] = dp if len(dp) > 1 else dp[0]
        return P(*dims)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


# ===========================================================================
# Optimizer
# ===========================================================================

def _is_ps(x) -> bool:
    return isinstance(x, P) or x is None


def opt_pspecs(opt, param_ps, mesh: Optional[Mesh] = None):
    """Optimizer-state pspecs: master/m/v mirror the param layout; the
    step counter is replicated.  With ``mesh`` given, leaves that ended
    up replicated are additionally sharded over the data axes (ZeRO-2:
    optimizer memory scales down even where params stay replicated)."""
    def upgrade(ps, leaf):
        if ps is None:
            ps = P()
        if any(d is not None for d in ps):
            return ps
        dp = _dp_tuple(mesh)
        dp_size = _axes_size(mesh, dp)
        if dp_size <= 1:
            return ps
        shape = leaf.shape
        for i in sorted(range(len(shape)), key=lambda i: -shape[i]):
            if shape[i] % dp_size == 0 and shape[i] >= dp_size:
                dims = [None] * len(shape)
                dims[i] = dp if len(dp) > 1 else dp[0]
                return P(*dims)
        return ps

    out = {}
    for key in ("master", "m", "v"):
        if mesh is not None:
            out[key] = jax.tree_util.tree_map(upgrade, param_ps, opt[key],
                                              is_leaf=_is_ps)
        else:
            out[key] = param_ps
    out["step"] = P()
    return out


# ===========================================================================
# Decode cache
# ===========================================================================

def cache_pspecs(cache, mesh: Mesh, batch: int, mode: str = "head"):
    """Pspecs for the pre-allocated decode cache.

    Leaves are stacked per layer: KV caches are (L, b, s, kv_heads, dh),
    MLA latents (L, b, s, r), SSM states (L, b, ...).  The batch dim is
    sharded over the data axes; ``mode`` picks where the model axis goes:

      head  KV-head (or feature) sharding — no resharding vs the
            per-layer TP attention math; the production serving default.
      seq   sequence sharding — balances long-context cache memory at
            the cost of one gather per step (the dry run's "opt" decode
            variant measures exactly that trade).
    """
    if mode not in ("head", "seq"):
        raise ValueError(f"unknown cache mode {mode!r}")
    dp = _dp_tuple(mesh)
    dp_size = _axes_size(mesh, dp)
    _, model = mesh_axes(mesh)
    model_size = mesh.shape[model]
    bdim = dp if len(dp) > 1 else dp[0]

    def leaf_spec(leaf):
        shape = leaf.shape
        ndim = len(shape)
        if ndim < 2:
            return P()
        # locate the batch dim (dim 0 of unstacked leaves, dim 1 stacked)
        b_at = next((i for i in (1, 0) if i < ndim and shape[i] == batch),
                    None)
        dims: list = [None] * ndim
        if (b_at is not None and dp_size > 1
                and shape[b_at] % dp_size == 0):
            dims[b_at] = bdim
        if model_size > 1 and b_at is not None:
            if mode == "seq" and b_at + 1 < ndim and \
                    shape[b_at + 1] % model_size == 0:
                dims[b_at + 1] = model
            elif mode == "head":
                # prefer the heads dim (b+2); fall back to the last dim
                for i in (b_at + 2, ndim - 1):
                    if i < ndim and i != b_at and dims[i] is None \
                            and i != b_at + 1 and \
                            shape[i] % model_size == 0:
                        dims[i] = model
                        break
        return P(*dims)

    return jax.tree_util.tree_map(leaf_spec, cache)


# ===========================================================================
# Materialization
# ===========================================================================

def shardings_from_pspecs(pspecs, mesh: Mesh):
    """Tree of NamedShardings from a tree of PartitionSpecs (None leaves
    become fully-replicated shardings, matching jit's convention)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s if isinstance(s, P) else P()),
        pspecs, is_leaf=lambda x: isinstance(x, P) or x is None)
