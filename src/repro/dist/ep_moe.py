"""Expert-parallel MoE FFN: ``shard_map`` over an expert-sharded mesh.

The single-device reference (``repro.models.moe.moe_ffn``) sorts
token-expert pairs and runs one grouped GEMM.  At scale the expert
tables live sharded over the ``model`` mesh axis, and each decode step
runs the paper's dispatch -> expert FFN -> combine pipeline (Sec. 3.3)
across chips:

  1. every shard routes its LOCAL tokens (router weights replicated),
  2. token activations are packed into per-expert capacity buffers and
     exchanged with one ``all_to_all`` (dispatch),
  3. each shard runs its resident experts' FFN as one batched GEMM over
     the received buffers,
  4. a second ``all_to_all`` returns expert outputs to the token's home
     shard, where the weighted combine (eta = 2 accesses, Eq. 17) runs.

Capacity semantics match production EP stacks: each (source shard,
expert) pair owns ``capacity`` token slots; overflow tokens are dropped
from that expert's contribution (their routing weight is simply lost),
which keeps the exchange statically shaped.  ``capacity_factor`` large
enough (>= E/k) guarantees zero drops and bit-compatible-modulo-
summation-order agreement with the reference.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.arch import FFNSpec
from repro.core.granularity import round_up
from repro.models.moe import route_topk

Array = jax.Array


def _pad_experts(w: Array, e_pad: int) -> Array:
    e = w.shape[0]
    if e_pad == e:
        return w
    pad = jnp.zeros((e_pad - e, *w.shape[1:]), w.dtype)
    return jnp.concatenate([w, pad], axis=0)


def ep_moe_ffn(params: Dict, f: FFNSpec, x: Array, mesh: Mesh, *,
               axis: str = "model", capacity_factor: float = 1.0) -> Array:
    """Expert-parallel ``moe_ffn`` forward.

    x: (T, d) global token activations, sharded ``P(axis, None)``;
    returns (T, d) with the same sharding.  Numerically matches
    ``moe_ffn(params, f, x)[0]`` when no capacity drops occur.
    """
    n_ep = mesh.shape[axis]
    e, k = f.n_experts, f.top_k
    d = x.shape[-1]
    if x.ndim != 2:
        raise ValueError(f"ep_moe_ffn expects (T, d) tokens, got {x.shape}")
    if x.shape[0] % n_ep:
        raise ValueError(f"T={x.shape[0]} not divisible by EP size {n_ep}")
    t_loc = x.shape[0] // n_ep
    # experts padded so every shard holds the same number of tables;
    # the router never selects a padded expert, so its zero weights are dead
    e_pad = round_up(e, n_ep)
    e_loc = e_pad // n_ep
    # per-(source shard, expert) slot count; t_loc always suffices because
    # top-k indices are distinct per token
    cap = int(math.ceil(capacity_factor * t_loc * k / e))
    cap = max(1, min(cap, t_loc))
    swiglu = f.activation == "swiglu"

    w_up = _pad_experts(params["w_up"], e_pad)
    w_down = _pad_experts(params["w_down"], e_pad)
    w_gate = _pad_experts(params["w_gate"], e_pad) if swiglu else None
    router = params["router"]

    def local(xs, router, w_up, w_gate, w_down):
        # xs: (t_loc, d) — this shard's resident tokens
        weights, top_idx, _ = route_topk(router, xs, k)
        tk = t_loc * k
        flat_e = top_idx.reshape(-1)                       # (tk,)
        flat_w = weights.reshape(-1)                       # (tk,) f32
        tok_of_pair = jnp.arange(tk, dtype=jnp.int32) // k
        # rank of each pair within its expert's buffer (pair order)
        onehot = (flat_e[:, None] == jnp.arange(e_pad, dtype=jnp.int32)[None]
                  ).astype(jnp.int32)
        rank = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(tk), flat_e]
        keep = rank < cap                                   # capacity drop
        # --- dispatch: pack (e_pad, cap, d) buffers, one all_to_all -------
        buf = jnp.zeros((e_pad, cap, d), xs.dtype)
        buf = buf.at[flat_e, rank].set(
            jnp.where(keep[:, None], xs[tok_of_pair], 0), mode="drop")
        buf = buf.reshape(n_ep, e_loc, cap, d)
        recv = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                                  tiled=True)               # (n_ep src, ...)
        # --- expert FFN: batched GEMM over this shard's experts -----------
        xr = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_ep * cap, d)
        up = jnp.einsum("ecd,edf->ecf", xr, w_up)
        if swiglu:
            gate = jnp.einsum("ecd,edf->ecf", xr, w_gate)
            h = (jax.nn.silu(gate.astype(jnp.float32))
                 * up.astype(jnp.float32)).astype(xs.dtype)
        else:
            h = jax.nn.gelu(up.astype(jnp.float32)).astype(xs.dtype)
        out_e = jnp.einsum("ecf,efd->ecd", h, w_down)
        # --- return trip + weighted combine at the token's home shard -----
        back = out_e.reshape(e_loc, n_ep, cap, d).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(back, axis, split_axis=0, concat_axis=0,
                                 tiled=True)
        ret = ret.reshape(e_pad, cap, d)
        pair_out = ret[flat_e, jnp.clip(rank, 0, cap - 1)]
        contrib = (pair_out.astype(jnp.float32)
                   * jnp.where(keep, flat_w, 0.0)[:, None])
        out = jnp.zeros((t_loc, d), jnp.float32).at[tok_of_pair].add(contrib)
        return out.astype(xs.dtype)

    mapped = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(None, None), P(axis, None, None),
                  (P(axis, None, None) if swiglu else P()),
                  P(axis, None, None)),
        out_specs=P(axis, None),
        check_rep=False)
    out = mapped(x, router,
                 w_up, w_gate if swiglu else jnp.zeros(()), w_down)

    if f.n_shared_experts:
        sh = jax.nn.gelu((x @ params["shared_up"]).astype(jnp.float32))
        out = out + (sh.astype(x.dtype) @ params["shared_down"])
    return out
