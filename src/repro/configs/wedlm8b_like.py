"""Paper-analogue dense model (WeDLM-8B, paper App. G.2):
36L d_model=4096 d_ff=12288 32H kv=8 head_dim=128 — used for the paper's
dense model-level validation (Fig. 26-29).
"""
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="wedlm8b-like",
        family="dense",
        n_layers=36,
        d_model=4096,
        vocab_size=151936,
        attention=AttentionSpec(kind="gqa", n_heads=32, n_kv_heads=8,
                                head_dim=128),
        ffn=FFNSpec(kind="dense", d_ff=12288, activation="swiglu"),
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="wedlm-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=2,
                                head_dim=16),
        ffn=FFNSpec(kind="dense", d_ff=128, activation="swiglu"),
    )
