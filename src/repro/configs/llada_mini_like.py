"""Paper-analogue MoE model (LLaDA-2.1-mini, paper App. G.3):
20L d_model=2048 d_ff=5120 16H kv=4 head_dim=128, MoE E=256 k=8
moe_d_ff=512 — used for the paper's MoE model-level validation
(Fig. 30-37).
"""
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="llada-2.1-mini-like",
        family="moe",
        n_layers=20,
        d_model=2048,
        vocab_size=128000,
        attention=AttentionSpec(kind="gqa", n_heads=16, n_kv_heads=4,
                                head_dim=128),
        ffn=FFNSpec(kind="moe", d_ff=512, activation="swiglu",
                    n_experts=256, top_k=8),
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="llada-mini-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=2,
                                head_dim=16),
        ffn=FFNSpec(kind="moe", d_ff=32, activation="swiglu",
                    n_experts=16, top_k=2),
    )
