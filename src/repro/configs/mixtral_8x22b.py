"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384,
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
"""
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        vocab_size=32768,
        attention=AttentionSpec(kind="swa", n_heads=48, n_kv_heads=8,
                                head_dim=128, window=4096),
        ffn=FFNSpec(kind="moe", d_ff=16384, activation="swiglu",
                    n_experts=8, top_k=2),
        rope_theta=1000000.0,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="swa", n_heads=4, n_kv_heads=2,
                                head_dim=16, window=8),
        ffn=FFNSpec(kind="moe", d_ff=64, activation="swiglu",
                    n_experts=4, top_k=2),
    )
