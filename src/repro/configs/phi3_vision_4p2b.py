"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192,
vocab=32064 — phi3-mini backbone + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

Backbone only per the brief: the CLIP vision tower is a STUB;
``input_specs()`` provides precomputed patch embeddings merged into the
token-embedding stream (``inputs["embeds"]``).
"""
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        n_layers=32,
        d_model=3072,
        vocab_size=32064,
        attention=AttentionSpec(kind="gqa", n_heads=32, n_kv_heads=32,
                                head_dim=96),
        ffn=FFNSpec(kind="dense", d_ff=8192, activation="swiglu"),
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="phi3-vision-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=4,
                                head_dim=16),
        ffn=FFNSpec(kind="dense", d_ff=128, activation="swiglu"),
    )
