"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288,
vocab=49152 — GQA, RoPE.  [arXiv:2402.19173; hf]
"""
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        vocab_size=49152,
        attention=AttentionSpec(kind="gqa", n_heads=24, n_kv_heads=2,
                                head_dim=128),
        ffn=FFNSpec(kind="dense", d_ff=12288, activation="gelu"),
        rope_theta=100000.0,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=1,
                                head_dim=16),
        ffn=FFNSpec(kind="dense", d_ff=128, activation="gelu"),
    )
