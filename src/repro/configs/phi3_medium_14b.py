"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920,
vocab=100352 — RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]
"""
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="phi3-medium-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        vocab_size=100352,
        attention=AttentionSpec(kind="gqa", n_heads=40, n_kv_heads=10,
                                head_dim=128),
        ffn=FFNSpec(kind="dense", d_ff=17920, activation="swiglu"),
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="phi3-medium-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=2,
                                head_dim=16),
        ffn=FFNSpec(kind="dense", d_ff=128, activation="swiglu"),
    )
