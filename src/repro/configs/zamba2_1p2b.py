"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192,
vocab=32000, ssm_state=64 — Mamba2 blocks + shared attention blocks.
[arXiv:2411.15242; hf]

A single shared attention+MLP block (one parameter set) is applied at
every 6th position, zamba2-style; remaining layers are Mamba2.
"""
from repro.core.arch import (LAYER_HYBRID, LAYER_SSM, ArchConfig,
                             AttentionSpec, FFNSpec, SSMSpec)


def _pattern(n_layers: int, period: int = 6):
    return tuple(LAYER_HYBRID if (i + 1) % period == 0 else LAYER_SSM
                 for i in range(n_layers))


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        vocab_size=32000,
        attention=AttentionSpec(kind="gqa", n_heads=32, n_kv_heads=32,
                                head_dim=64),
        ffn=FFNSpec(kind="none", d_ff=8192, activation="gelu"),
        ssm=SSMSpec(kind="mamba2", d_state=64, d_conv=4, expand=2,
                    head_dim=64, n_groups=1),
        layer_pattern=_pattern(38),
        shared_attention=True,
        tie_embeddings=True,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=4,
                                head_dim=16),
        ffn=FFNSpec(kind="none", d_ff=128, activation="gelu"),
        ssm=SSMSpec(kind="mamba2", d_state=16, d_conv=4, expand=2,
                    head_dim=32, n_groups=1),
        layer_pattern=_pattern(4, period=2),
        shared_attention=True,
        tie_embeddings=True,
    )
