"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA.
[hf:openbmb/MiniCPM3-4B; hf]

MLA geometry from the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64.
"""
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        vocab_size=73448,
        attention=AttentionSpec(kind="mla", n_heads=40, n_kv_heads=40,
                                head_dim=96, q_lora_rank=768,
                                kv_lora_rank=256, qk_nope_head_dim=64,
                                qk_rope_head_dim=32, v_head_dim=64),
        ffn=FFNSpec(kind="dense", d_ff=6400, activation="swiglu"),
        tie_embeddings=True,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="mla", n_heads=4, n_kv_heads=4,
                                head_dim=24, q_lora_rank=32,
                                kv_lora_rank=16, qk_nope_head_dim=16,
                                qk_rope_head_dim=8, v_head_dim=16),
        ffn=FFNSpec(kind="dense", d_ff=128, activation="swiglu"),
        tie_embeddings=True,
    )
