"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512,
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Assignment note: the brief lists "MoE 40e top-8 — 32 experts top-8"; we
follow the explicit "40e" figure (E is a single config field either way —
see DESIGN.md §6).
"""
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        vocab_size=49155,
        attention=AttentionSpec(kind="gqa", n_heads=24, n_kv_heads=8,
                                head_dim=64),
        ffn=FFNSpec(kind="moe", d_ff=512, activation="swiglu",
                    n_experts=40, top_k=8),
        tie_embeddings=True,
        rope_theta=10000.0,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=2,
                                head_dim=16),
        ffn=FFNSpec(kind="moe", d_ff=32, activation="swiglu",
                    n_experts=8, top_k=2),
        tie_embeddings=True,
    )
