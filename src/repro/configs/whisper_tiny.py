"""whisper-tiny [audio]: 4L d_model=384 6H (MHA) d_ff=1536 vocab=51865 —
enc-dec, conv frontend (stub).  [arXiv:2212.04356; unverified]

The mel/conv frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings (1500 frames x d_model) for the encoder.
"""
from repro.core.arch import (ArchConfig, AttentionSpec, EncoderSpec, FFNSpec)


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,
        d_model=384,
        vocab_size=51865,
        attention=AttentionSpec(kind="gqa", n_heads=6, n_kv_heads=6,
                                head_dim=64),
        ffn=FFNSpec(kind="dense", d_ff=1536, activation="gelu"),
        encoder=EncoderSpec(n_layers=4, n_frames=1500, frontend="audio"),
        max_seq_len=65536,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=4,
                                head_dim=16),
        ffn=FFNSpec(kind="dense", d_ff=128, activation="gelu"),
        encoder=EncoderSpec(n_layers=2, n_frames=16, frontend="audio"),
    )
