"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — mamba1 architecture.  [arXiv:2410.05355; unverified]

Attention-free: the NFP attention-granularity term is INAPPLICABLE here
(DESIGN.md §6 / §Arch-applicability) — the model-level NFP boundary is
min(SSM idle-compute term, scan-chunk granularity).
"""
from repro.core.arch import (LAYER_SSM, ArchConfig, AttentionSpec, FFNSpec,
                             SSMSpec)


def config() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        vocab_size=65024,
        attention=None,
        ffn=FFNSpec(kind="none", d_ff=0),
        ssm=SSMSpec(kind="mamba1", d_state=16, d_conv=4, expand=2),
        layer_pattern=tuple([LAYER_SSM] * 64),
        tie_embeddings=True,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=None,
        ffn=FFNSpec(kind="none", d_ff=0),
        ssm=SSMSpec(kind="mamba1", d_state=8, d_conv=4, expand=2),
        layer_pattern=tuple([LAYER_SSM] * 2),
        tie_embeddings=True,
    )
