"""repro.configs — assigned architectures (+ paper-analogue configs).

Every architecture is selectable by id: ``get_config("<arch-id>")`` and
``get_config("<arch-id>", reduced=True)`` for the CPU smoke variant.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.arch import ArchConfig

_REGISTRY: Dict[str, "module"] = {}

ARCH_IDS = [
    "granite_moe_3b_a800m",
    "mixtral_8x22b",
    "minicpm3_4b",
    "starcoder2_3b",
    "phi3_medium_14b",
    "stablelm_3b",
    "zamba2_1p2b",
    "whisper_tiny",
    "phi3_vision_4p2b",
    "falcon_mamba_7b",
]

# paper-analogue configs (model-level validation targets of the paper)
PAPER_IDS = ["wedlm8b_like", "llada_mini_like"]


def _norm(name: str) -> str:
    return (name.replace("-", "_").replace(".", "p"))


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.reduced_config() if reduced else mod.config()


def all_configs(reduced: bool = False) -> Dict[str, ArchConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}
