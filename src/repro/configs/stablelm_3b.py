"""stablelm-3b [dense]: 32L d_model=2560 32H (GQA kv=32 = MHA) d_ff=6912,
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.core.arch import ArchConfig, AttentionSpec, FFNSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        vocab_size=50304,
        attention=AttentionSpec(kind="gqa", n_heads=32, n_kv_heads=32,
                                head_dim=80),
        ffn=FFNSpec(kind="dense", d_ff=6912, activation="swiglu"),
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        attention=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=4,
                                head_dim=16),
        ffn=FFNSpec(kind="dense", d_ff=128, activation="swiglu"),
    )
